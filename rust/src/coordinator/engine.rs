//! The centralized engine (§3.1–§3.2): per-model queues, pluggable batch
//! scheduling (default: the paper's oldest-first discipline), swap
//! decisions, admission control, and load-dependency enforcement.
//!
//! The engine is a *passive* state machine: backends (the discrete-event
//! simulator in `sim/`, the thread-based real runtime in `serving/`) feed
//! it arrivals and completion acks and drain its action outbox. This keeps
//! the paper's coordination logic in exactly one place, testable without
//! any backend.
//!
//! Invariants enforced here (the paper's ordering rules):
//! - a batch entry for model M is submitted only while M is `Resident`
//!   (all workers acked M's load) — the load dependency;
//! - a resident model with in-flight batch entries is never chosen as an
//!   eviction victim — evicting it would invalidate entries already in
//!   the pipes;
//! - offload of the victim and load of the requested model are issued
//!   back-to-back so the backend can overlap them (swap ≈ max, not sum).

use std::collections::HashMap;

use crate::cluster::hosttier::SwapTier;
use crate::config::EngineConfig;
use crate::coordinator::entry::{
    BatchEntry, Entry, EntryId, LoadDirection, LoadEntry, ModelId, Request, RequestId,
};
use crate::coordinator::prefetch::MarkovPredictor;
use crate::coordinator::queues::RequestQueues;
use crate::coordinator::scheduler::{self, Candidate, ModelCost, SchedCtx, Scheduler};
use crate::coordinator::swap::{Residency, SwapManager, SwapPlan, SwapStats};

/// Completion record for one request (drives every latency table/CDF).
#[derive(Clone, Debug, PartialEq)]
pub struct RequestRecord {
    pub id: RequestId,
    /// Catalog model id. The engine records its own (group-local) index;
    /// multi-group backends remap to the catalog index when merging
    /// per-group reports (`sim::SimCluster`).
    pub model: ModelId,
    pub arrival: f64,
    /// Latency deadline (`arrival + SLO`); `f64::INFINITY` when the
    /// model has no SLO target.
    pub deadline: f64,
    /// When the request's batch entry was submitted to workers.
    pub batch_submit: f64,
    /// When the batch's output returned to the engine.
    pub done: f64,
    pub batch_size: usize,
    /// Engine group that served the request (0 in a single-group
    /// deployment; set by the cluster backend when merging).
    pub group: usize,
}

impl RequestRecord {
    /// End-to-end latency (the paper's reported metric).
    pub fn latency(&self) -> f64 {
        self.done - self.arrival
    }

    /// Time spent queued at the engine (includes swap waits).
    pub fn queue_time(&self) -> f64 {
        self.batch_submit - self.arrival
    }

    /// True iff the request completed within its SLO deadline.
    pub fn attained(&self) -> bool {
        self.done <= self.deadline
    }
}

/// Why a request was dropped instead of completed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DropReason {
    /// Admission control / shedding: the deadline was provably
    /// infeasible (the `shed` scheduler's verdict).
    Infeasible,
    /// The hosting group failed and the `RetryPolicy` budget was
    /// exhausted (fault injection, DESIGN.md §11).
    Fault,
}

/// Record of one request rejected, shed, or lost. Admission control
/// (the `shed` scheduler) produces `Infeasible` drops; the cluster's
/// fault layer produces `Fault` drops for requests a failed group could
/// not re-home within its retry budget.
#[derive(Clone, Debug, PartialEq)]
pub struct DropRecord {
    pub id: RequestId,
    pub model: ModelId,
    pub arrival: f64,
    pub deadline: f64,
    /// When the drop decision was made (== `arrival` for rejections at
    /// admission, later for requests shed while queued).
    pub dropped_at: f64,
    /// The model's residency state at the drop decision — determines
    /// which lower bounds made the deadline provably infeasible.
    pub residency: Residency,
    /// Engine group that dropped the request (0 single-group).
    pub group: usize,
    pub reason: DropReason,
}

/// Completion record for one swap (offload+load pair or bare load),
/// measured the way §5.1 measures: from submission of the first entry to
/// completion of both.
#[derive(Clone, Debug, PartialEq)]
pub struct SwapRecord {
    pub load_model: ModelId,
    pub victim: Option<ModelId>,
    pub submitted: f64,
    pub completed: f64,
    /// Submission → first chunk of the load resident on every worker —
    /// the moment stage-0 compute may begin under the chunked pipeline.
    /// For a monolithic load the whole shard is the first chunk, so this
    /// equals the load's own completion latency.
    pub time_to_first_chunk: f64,
    /// Fraction of the load's chunks that landed while a batch for the
    /// loading model was already in flight, i.e. how much of the transfer
    /// the engine managed to hide behind compute. Always 0 for monolithic
    /// loads (batches are gated on full residency).
    pub overlap_fraction: f64,
    /// True when the load was cancelled mid-transfer; `completed` is then
    /// the cancellation-ack time and the model ended `Offloaded`.
    pub cancelled: bool,
    /// The loaded model's largest per-GPU shard, bytes — *that model's*
    /// own footprint from the per-model cost model, not the fleet
    /// maximum. 0 when the backend supplied no cost model (real mode).
    /// Under delta swapping this is the bytes actually transferred (the
    /// delta), not the full shard.
    pub bytes: usize,
    /// Where the load's bytes came from (DESIGN.md §12): pinned host
    /// memory, or staged up from NVMe first. Always `HostHit` without a
    /// host-tier config — the paper's infinite-warm-host assumption.
    pub tier: SwapTier,
    /// H2D bytes *not* transferred because the model's base was GPU
    /// resident and only the delta moved. 0 for standalone models and
    /// full-form loads.
    pub delta_bytes_saved: usize,
    /// Engine group that performed the swap (0 single-group).
    pub group: usize,
}

impl SwapRecord {
    pub fn duration(&self) -> f64 {
        self.completed - self.submitted
    }
}

struct InflightLoad {
    model: ModelId,
    dir: LoadDirection,
    acks_remaining: usize,
    /// Index into `swap_pairs`.
    pair: usize,
    /// Worker acks received per non-final chunk (chunked loads only;
    /// empty for monolithic loads, offloads, and cancels).
    chunk_acks: Vec<usize>,
    /// A cancel entry for this load is in flight: ignore its remaining
    /// chunk/load acks; the cancel entry resolves it.
    cancelled: bool,
    /// For `dir == Cancel`: the load entry this cancels.
    target: Option<EntryId>,
}

struct SwapPair {
    load_model: ModelId,
    victim: Option<ModelId>,
    submitted: f64,
    /// Entries not yet fully acked (1 or 2).
    outstanding: usize,
    completed: Option<f64>,
    /// Chunks in the load entry (1 for monolithic transfers).
    total_chunks: usize,
    /// When the load's first chunk was acked by every worker.
    first_chunk_at: Option<f64>,
    /// Chunks that landed while the loading model had in-flight batches.
    overlapped_chunks: usize,
    cancelled: bool,
    /// Tier provenance of the load, annotated by the backend at dispatch
    /// time (`Engine::annotate_load`); `HostHit` until told otherwise.
    tier: SwapTier,
    /// Backend override for the record's `bytes` (the delta transfer
    /// size under delta swapping); `None` keeps the cost-model shard.
    bytes_override: Option<usize>,
    /// H2D bytes saved by delta dedup (annotated with `bytes_override`).
    delta_saved: usize,
}

/// Slot states for `RecordSlab`.
enum RecordSlot {
    /// Admitted, record not produced yet.
    Pending,
    /// Completed; the record waits to be drained in completion order.
    Done(RequestRecord),
    /// Exited without a record (shed, deadline drop, fail harvest) or
    /// already drained; retired when the contiguous prefix advances.
    Drained,
}

/// Vec-backed slab of completed-request records keyed by request id
/// (the last allocation item of ROADMAP item 4). Admission reserves
/// the slot in id order, completion writes the record in place, and
/// draining walks the completion-order index — so the full-retention
/// path grows one arena in arrival order instead of pushing records
/// interleaved with the drop/swap vectors, while the drained prefix
/// retires after each streaming drain to keep the slab O(live
/// requests) on 10M-request traces.
#[derive(Default)]
struct RecordSlab {
    /// Request id of `slots[0]`.
    base: RequestId,
    slots: Vec<RecordSlot>,
    /// Completion order — the drain order the report contract pins.
    done: Vec<RequestId>,
}

impl RecordSlab {
    /// Reserve the slot for a freshly assigned id (ids are monotone, so
    /// this is always a push).
    fn admit(&mut self, id: RequestId) {
        debug_assert_eq!(id, self.base + self.slots.len() as RequestId, "ids admit in order");
        self.slots.push(RecordSlot::Pending);
    }

    fn slot(&mut self, id: RequestId) -> &mut RecordSlot {
        &mut self.slots[(id - self.base) as usize]
    }

    /// The request completed: write its record into the reserved slot.
    fn complete(&mut self, id: RequestId, record: RequestRecord) {
        let slot = self.slot(id);
        debug_assert!(matches!(slot, RecordSlot::Pending), "double completion for {id}");
        *slot = RecordSlot::Done(record);
        self.done.push(id);
    }

    /// The request exited without a record (shed / dropped / harvested).
    fn retire(&mut self, id: RequestId) {
        *self.slot(id) = RecordSlot::Drained;
    }

    /// Append the finished records to `out` in completion order, then
    /// retire the slab's drained prefix (everything stays, with its
    /// capacity, for the next round).
    fn drain_into(&mut self, out: &mut Vec<RequestRecord>) {
        out.reserve(self.done.len());
        for i in 0..self.done.len() {
            let id = self.done[i];
            let slot = std::mem::replace(self.slot(id), RecordSlot::Drained);
            match slot {
                RecordSlot::Done(record) => out.push(record),
                _ => unreachable!("done index points at a non-Done slot"),
            }
        }
        self.done.clear();
        let retired =
            self.slots.iter().take_while(|s| matches!(s, RecordSlot::Drained)).count();
        self.slots.drain(..retired);
        self.base += retired as RequestId;
    }

    /// Drain everything into a fresh vector (full-retention path).
    fn take_all(&mut self) -> Vec<RequestRecord> {
        let mut out = Vec::new();
        self.drain_into(&mut out);
        out
    }
}

/// The engine.
pub struct Engine {
    cfg: EngineConfig,
    /// Worker-acks required per load entry (= tp·pp workers).
    world: usize,
    /// Max in-flight batch entries per model before the engine stops
    /// draining that queue (fills the PP pipeline without starving
    /// batching; default = pp). See DESIGN.md §5.
    max_inflight_per_model: usize,
    queues: RequestQueues,
    swap: SwapManager,
    /// Scheduling / admission discipline (DESIGN.md §5); built from
    /// `cfg.scheduler` via the `coordinator::scheduler` registry.
    scheduler: Box<dyn Scheduler>,
    /// Per-model SLO target in seconds (deadline = arrival + SLO);
    /// `f64::INFINITY` means no deadline.
    slos: Vec<f64>,
    /// Per-model cost-model constants for SLO-aware disciplines (see
    /// `scheduler::ModelCost`): each catalog entry's own swap cost and
    /// cold-load floor, derived from its own shard bytes.
    costs: Vec<ModelCost>,
    /// Fleet-wide lower bound on batch-submit → completion time.
    exec_floor: f64,
    /// Per-model priority weights (`ModelDeployment::weight`; 1.0 =
    /// neutral), consumed by `swap-aware`.
    weights: Vec<f64>,
    inflight_batches: HashMap<EntryId, BatchEntry>,
    inflight_per_model: Vec<usize>,
    inflight_loads: HashMap<EntryId, InflightLoad>,
    swap_pairs: Vec<SwapPair>,
    /// Per-model chunks per load entry under the chunked pipeline; 1 (the
    /// default) means monolithic transfers for that model, in which case
    /// the engine behaves exactly like the async design regardless of
    /// `cfg.load_design` — the `chunk_layers = all` equivalence invariant
    /// (DESIGN.md §6). Heterogeneous catalogs get different counts per
    /// model (a model's layer count determines its plan).
    chunks_per_load: Vec<usize>,
    /// Models with a cancel entry in flight (no early batches for them).
    cancelling: Vec<bool>,
    next_entry: EntryId,
    next_request: RequestId,
    outbox: Vec<Entry>,
    /// Completed-request records, arena-allocated by request id and
    /// drained in completion order (see `RecordSlab`).
    completed: RecordSlab,
    dropped: Vec<DropRecord>,
    swap_records: Vec<SwapRecord>,
    /// Monotone count of every drop ever recorded, unaffected by
    /// draining `dropped` — closed-loop drivers compare before/after
    /// snapshots of this to detect drops caused by the call they just
    /// made, which must keep working when a streaming backend drains
    /// `dropped` mid-run.
    drops_total: u64,
    /// Fine-tune lineage (group-local ids): `bases[v] = Some(b)` marks v
    /// a delta variant of b. Drives base protection: a base is never an
    /// eviction victim while a dependent variant is non-Offloaded.
    bases: Vec<Option<ModelId>>,
    /// Fast-path flag: no entry has a base, so every eviction filter
    /// stays bit-for-bit the legacy predicate.
    has_bases: bool,
    /// Scratch for the per-plan base-protection mask (see
    /// `recompute_protected`; reused so planning never allocates).
    protected_buf: Vec<bool>,
    /// Scratch for `pump`'s per-round candidate ranking (reused across
    /// rounds and calls so the hot loop never allocates).
    cand_buf: Vec<Candidate>,
    batch_submit_times: HashMap<EntryId, f64>,
    predictor: MarkovPredictor,
    prefetches_issued: u64,
}

impl Engine {
    pub fn new(num_models: usize, world: usize, pp: usize, cfg: EngineConfig, seed: u64) -> Engine {
        Engine {
            cfg,
            world,
            max_inflight_per_model: pp.max(1),
            queues: RequestQueues::new(num_models),
            swap: SwapManager::new(num_models, cfg.resident_cap, cfg.policy, seed),
            scheduler: scheduler::make(cfg.scheduler),
            slos: vec![f64::INFINITY; num_models],
            costs: vec![ModelCost::default(); num_models],
            exec_floor: 0.0,
            weights: vec![1.0; num_models],
            inflight_batches: HashMap::new(),
            inflight_per_model: vec![0; num_models],
            inflight_loads: HashMap::new(),
            swap_pairs: Vec::new(),
            chunks_per_load: vec![1; num_models],
            cancelling: vec![false; num_models],
            next_entry: 0,
            next_request: 0,
            outbox: Vec::new(),
            completed: RecordSlab::default(),
            dropped: Vec::new(),
            swap_records: Vec::new(),
            drops_total: 0,
            bases: vec![None; num_models],
            has_bases: false,
            protected_buf: vec![false; num_models],
            cand_buf: Vec::new(),
            batch_submit_times: HashMap::new(),
            predictor: MarkovPredictor::with_min_count(
                num_models,
                cfg.prefetch_min_count.max(1),
            ),
            prefetches_issued: 0,
        }
    }

    /// Override the per-model in-flight batch limit (ablation knob).
    pub fn set_max_inflight_per_model(&mut self, n: usize) {
        assert!(n >= 1);
        self.max_inflight_per_model = n;
    }

    /// Set per-model SLO targets in seconds (deadline = arrival + SLO).
    /// Entries must be positive; use `f64::INFINITY` for "no SLO".
    pub fn set_slos(&mut self, slos: &[f64]) {
        assert_eq!(slos.len(), self.slos.len(), "one SLO per model");
        assert!(slos.iter().all(|s| *s > 0.0), "SLO targets must be positive");
        self.slos.copy_from_slice(slos);
    }

    /// Provide the scheduler's cost model: one `ModelCost` per catalog
    /// entry (that model's own swap-in estimate, cold-load floor, and
    /// shard bytes — see `scheduler::ModelCost`), plus the fleet-wide
    /// `exec_floor` lower bound on batch-submit→completion time. All
    /// default to zero, which disables amortization and makes shedding
    /// maximally conservative. Each cost's `chunked` flag is derived by
    /// the engine from its chunk plan (`set_chunks_per_load`), not from
    /// the supplied value.
    pub fn set_cost_model(&mut self, costs: Vec<ModelCost>, exec_floor: f64) {
        assert_eq!(costs.len(), self.slos.len(), "one ModelCost per model");
        assert!(
            exec_floor >= 0.0
                && costs.iter().all(|c| c.swap_cost >= 0.0 && c.swap_floor >= 0.0)
        );
        self.costs = costs;
        self.exec_floor = exec_floor;
    }

    /// Convenience for homogeneous fleets and tests: one cost for every
    /// model (exactly the pre-catalog global-constant behaviour).
    pub fn set_uniform_cost_model(&mut self, swap_cost: f64, swap_floor: f64, exec_floor: f64) {
        let n = self.slos.len();
        self.set_cost_model(
            vec![ModelCost { swap_cost, swap_floor, bytes: 0, chunked: false }; n],
            exec_floor,
        );
    }

    /// Set per-model priority weights (`ModelDeployment::weight`; all 1.0
    /// reproduces unweighted scheduling exactly).
    pub fn set_weights(&mut self, weights: &[f64]) {
        assert_eq!(weights.len(), self.weights.len(), "one weight per model");
        assert!(weights.iter().all(|w| *w > 0.0 && w.is_finite()));
        self.weights.copy_from_slice(weights);
    }

    /// The scheduling discipline in effect.
    pub fn scheduler_name(&self) -> &'static str {
        self.scheduler.name()
    }

    /// Declare the fine-tune lineage (group-local ids): `bases[v] =
    /// Some(b)` marks v a delta variant of base b (DESIGN.md §12). The
    /// eviction planner then refuses to evict a base while any dependent
    /// variant is non-Offloaded, and a variant never evicts its own base
    /// to make room for itself. An all-`None` vector (the default)
    /// leaves every eviction decision bit-for-bit unchanged.
    pub fn set_bases(&mut self, bases: Vec<Option<ModelId>>) {
        assert_eq!(bases.len(), self.protected_buf.len(), "one base slot per model");
        self.has_bases = bases.iter().any(|b| b.is_some());
        self.bases = bases;
    }

    /// Refresh `protected_buf`: mark every base whose dependents are not
    /// all Offloaded. Called right before each eviction plan; O(models),
    /// allocation-free, and skipped entirely without lineage.
    fn recompute_protected(&mut self) {
        if !self.has_bases {
            return;
        }
        self.protected_buf.iter_mut().for_each(|p| *p = false);
        for v in 0..self.bases.len() {
            if let Some(b) = self.bases[v] {
                if self.swap.state(v) != Residency::Offloaded {
                    self.protected_buf[b] = true;
                }
            }
        }
    }

    /// Backend annotation for an in-flight load entry's swap record: tier
    /// provenance (host hit vs NVMe miss), the actual bytes transferred
    /// (`Some` overrides the cost-model shard — the delta size under
    /// delta swapping), and the H2D bytes dedup saved. No-op for unknown
    /// entries and non-load directions, so backends may call it
    /// unconditionally from their dispatch path.
    pub fn annotate_load(
        &mut self,
        entry_id: EntryId,
        tier: SwapTier,
        bytes_override: Option<usize>,
        delta_bytes_saved: usize,
    ) {
        let Some(l) = self.inflight_loads.get(&entry_id) else { return };
        if l.dir != LoadDirection::Load {
            return;
        }
        let pair = &mut self.swap_pairs[l.pair];
        pair.tier = tier;
        pair.bytes_override = bytes_override;
        pair.delta_saved = delta_bytes_saved;
    }

    /// Configure the chunked swap pipeline: model `m`'s load entries
    /// transfer as `chunks[m]` layer-granular chunks (see
    /// `model::shard::chunk_plan` — per-model counts under a
    /// heterogeneous catalog). Only meaningful with
    /// `LoadDesign::ChunkedPipelined`; a count of 1 keeps that model's
    /// monolithic behaviour bit-for-bit.
    pub fn set_chunks_per_load(&mut self, chunks: Vec<usize>) {
        assert_eq!(chunks.len(), self.chunks_per_load.len(), "one chunk count per model");
        assert!(chunks.iter().all(|&n| n >= 1));
        self.chunks_per_load = chunks;
    }

    /// True when the chunked pipeline changes engine behaviour *for this
    /// model*: batches may be submitted to it while partially resident
    /// and its in-flight loads may be cancelled. A one-chunk plan is
    /// monolithic by definition.
    fn chunked_active(&self, model: ModelId) -> bool {
        self.cfg.load_design == crate::config::LoadDesign::ChunkedPipelined
            && self.chunks_per_load[model] > 1
    }

    /// This model's cost constants with the live `chunked` flag folded in.
    fn model_cost(&self, model: ModelId) -> ModelCost {
        ModelCost { chunked: self.chunked_active(model), ..self.costs[model] }
    }

    /// Deadline for a request for `model` arriving at `arrival`.
    pub fn deadline_for(&self, model: ModelId, arrival: f64) -> f64 {
        arrival + self.slos[model]
    }

    fn sched_ctx(&self, now: f64) -> SchedCtx {
        SchedCtx {
            now,
            max_batch_size: self.cfg.max_batch_size,
            exec_floor: self.exec_floor,
        }
    }

    /// Pre-warm initial residency (experiments start with some models
    /// loaded; counts against the cap).
    pub fn force_resident(&mut self, model: ModelId, now: f64) {
        self.swap.force_resident(model, now);
    }

    // ----- inputs -----

    /// A client request arrived. Returns its id. Call `drain_outbox`
    /// after. Under the `shed` scheduler a provably deadline-infeasible
    /// request is rejected instead of queued: it gets a `DropRecord`
    /// (see `take_dropped`) and never a `RequestRecord`.
    pub fn on_request(&mut self, now: f64, model: ModelId, input_len: usize) -> RequestId {
        let id = self.next_request;
        self.next_request += 1;
        // Reserve the record slot up front (shed requests retire it
        // below) so the slab's id keying stays gap-free.
        self.completed.admit(id);
        // The predictor observes every arrival, including ones shed below:
        // rejected traffic is still demand, and prefetching its model is
        // exactly what can make the *next* request feasible again.
        self.predictor.observe(model);
        let deadline = self.deadline_for(model, now);
        if self.scheduler.sheds()
            && !self.scheduler.admit(
                &self.sched_ctx(now),
                self.model_cost(model),
                deadline,
                self.swap.state(model),
            )
        {
            self.drops_total += 1;
            self.dropped.push(DropRecord {
                id,
                model,
                arrival: now,
                deadline,
                dropped_at: now,
                residency: self.swap.state(model),
                group: 0,
                reason: DropReason::Infeasible,
            });
            self.completed.retire(id);
            return id;
        }
        self.queues.push(Request { id, model, arrival: now, input_len });
        self.pump(now);
        if self.cfg.prefetch {
            self.maybe_prefetch(now, model);
        }
        id
    }

    /// §6 extension: speculatively swap in the predicted next model,
    /// evicting only a completely idle victim (no queued requests, no
    /// in-flight batches, and not the model just requested).
    fn maybe_prefetch(&mut self, now: f64, current: ModelId) {
        let Some(next) = self.predictor.predict_after(current) else { return };
        if self.queues.len(next) > 0 {
            return; // a real request is queued: the normal path handles it
        }
        self.recompute_protected();
        let inflight = &self.inflight_per_model;
        let queues = &self.queues;
        let prot = &self.protected_buf;
        let has_bases = self.has_bases;
        let own_base = if has_bases { self.bases[next] } else { None };
        let plan = self.swap.plan_prefetch(next, now, |m| {
            m != current
                && inflight[m] == 0
                && queues.len(m) == 0
                && (!has_bases || (!prot[m] && Some(m) != own_base))
        });
        match plan {
            Some(victim) => {
                self.prefetches_issued += 1;
                self.submit_swap_entries(now, next, victim);
            }
            None => {}
        }
    }

    /// Number of speculative loads issued so far.
    pub fn prefetches_issued(&self) -> u64 {
        self.prefetches_issued
    }

    /// Feed the Markov prefetcher a model-to-model transition observed
    /// *outside* this engine. In a multi-group cluster the router sees
    /// the global arrival sequence while each group's engine only
    /// observes the arrivals routed to it; the cluster backend injects
    /// the global transitions (translated to this engine's local model
    /// ids) so prefetch keeps learning cross-model patterns when traffic
    /// fans out across groups (DESIGN.md §8). No-op effect on anything
    /// but the predictor's counts.
    pub fn observe_external_transition(&mut self, prev: ModelId, next: ModelId) {
        self.predictor.record_transition(prev, next);
    }

    /// Total requests queued across every model (the cluster router's
    /// `least-loaded` signal, together with `inflight_batches`).
    pub fn queued_total(&self) -> usize {
        self.queues.total_len()
    }

    fn submit_swap_entries(&mut self, now: f64, model: ModelId, victim: Option<ModelId>) {
        self.submit_swap(now, model, victim);
    }

    /// Workers returned the output of a batch entry.
    pub fn on_batch_done(&mut self, now: f64, entry_id: EntryId) {
        let batch = self
            .inflight_batches
            .remove(&entry_id)
            .unwrap_or_else(|| panic!("unknown batch entry {entry_id}"));
        self.inflight_per_model[batch.model] -= 1;
        let submit = self.batch_submit_times.remove(&entry_id).expect("missing submit time");
        for req in batch.requests.iter() {
            self.completed.complete(req.id, RequestRecord {
                id: req.id,
                model: req.model,
                arrival: req.arrival,
                deadline: self.deadline_for(req.model, req.arrival),
                batch_submit: submit,
                done: now,
                batch_size: batch.batch_size(),
                group: 0,
            });
        }
        self.pump(now);
    }

    /// One worker acknowledged completion of a non-final chunk of a
    /// chunked load entry (chunks `0 .. total-1`; the final chunk acks as
    /// the load entry itself via `on_load_ack`). Once every worker has
    /// acked chunk `c`, the model advances to
    /// `PartiallyResident { loaded: c + 1, total }`.
    pub fn on_chunk_ack(&mut self, now: f64, entry_id: EntryId, chunk: usize) {
        // A chunk ack may trail a cancellation that already resolved the
        // entry — tolerated, not an error.
        let Some(inflight) = self.inflight_loads.get_mut(&entry_id) else { return };
        if inflight.cancelled || inflight.dir != LoadDirection::Load {
            return;
        }
        debug_assert!(chunk < inflight.chunk_acks.len(), "chunk index out of plan");
        inflight.chunk_acks[chunk] += 1;
        if inflight.chunk_acks[chunk] < self.world {
            return;
        }
        let model = inflight.model;
        let pair_idx = inflight.pair;
        let total = self.swap_pairs[pair_idx].total_chunks;
        // World-acks complete in chunk order (each worker acks its chunks
        // in order), but guard monotonicity anyway.
        let advance = match self.swap.state(model) {
            Residency::Loading => true,
            Residency::PartiallyResident { loaded, .. } => chunk + 1 > loaded,
            _ => false,
        };
        if advance {
            self.swap.on_chunk_loaded(model, chunk + 1, total);
        }
        let overlapped = self.inflight_per_model[model] > 0;
        let pair = &mut self.swap_pairs[pair_idx];
        if chunk == 0 && pair.first_chunk_at.is_none() {
            pair.first_chunk_at = Some(now);
        }
        if overlapped {
            pair.overlapped_chunks += 1;
        }
    }

    /// One worker acknowledged completion of a load entry.
    pub fn on_load_ack(&mut self, now: f64, entry_id: EntryId) {
        let finished = {
            let inflight = self
                .inflight_loads
                .get_mut(&entry_id)
                .unwrap_or_else(|| panic!("unknown load entry {entry_id}"));
            inflight.acks_remaining -= 1;
            // A cancelled load never completes from its own acks; the
            // cancel entry resolves it (and removes it) instead.
            inflight.acks_remaining == 0 && !inflight.cancelled
        };
        if !finished {
            return;
        }
        let inflight = self.inflight_loads.remove(&entry_id).unwrap();
        match inflight.dir {
            LoadDirection::Load => {
                let overlapped = self.inflight_per_model[inflight.model] > 0;
                let pair = &mut self.swap_pairs[inflight.pair];
                // The final chunk just landed everywhere; for monolithic
                // loads it is also the *first* chunk.
                if pair.first_chunk_at.is_none() {
                    pair.first_chunk_at = Some(now);
                }
                if overlapped {
                    pair.overlapped_chunks += 1;
                }
                self.swap.on_load_complete(inflight.model, now);
            }
            LoadDirection::Offload => self.swap.on_offload_complete(inflight.model),
            LoadDirection::Cancel => {
                let target = inflight.target.expect("cancel entry without target");
                self.inflight_loads.remove(&target);
                self.swap.on_load_cancelled(inflight.model);
                self.cancelling[inflight.model] = false;
                self.swap_pairs[inflight.pair].cancelled = true;
            }
        }
        self.settle_pair(inflight.pair, now);
        self.pump(now);
    }

    /// One member (offload, load, or the load's cancel) of a swap pair
    /// fully acked; record the pair once both members resolve.
    fn settle_pair(&mut self, pair_idx: usize, now: f64) {
        let done = {
            let pair = &mut self.swap_pairs[pair_idx];
            pair.outstanding -= 1;
            if pair.outstanding == 0 {
                pair.completed = Some(now);
                true
            } else {
                false
            }
        };
        if done {
            let pair = &self.swap_pairs[pair_idx];
            self.swap_records.push(SwapRecord {
                load_model: pair.load_model,
                victim: pair.victim,
                submitted: pair.submitted,
                completed: now,
                time_to_first_chunk: pair.first_chunk_at.unwrap_or(now) - pair.submitted,
                overlap_fraction: pair.overlapped_chunks as f64 / pair.total_chunks as f64,
                cancelled: pair.cancelled,
                bytes: pair.bytes_override.unwrap_or(self.costs[pair.load_model].bytes),
                tier: pair.tier,
                delta_bytes_saved: pair.delta_saved,
                group: 0,
            });
        }
    }

    // ----- outputs -----

    /// Entries to deliver to workers, in submission order.
    pub fn drain_outbox(&mut self) -> Vec<Entry> {
        std::mem::take(&mut self.outbox)
    }

    /// Append pending outbox entries to `out` (allocation-free variant
    /// of [`Engine::drain_outbox`] for the dispatch hot path: the caller
    /// keeps one scratch buffer alive across events).
    pub fn drain_outbox_into(&mut self, out: &mut Vec<Entry>) {
        out.append(&mut self.outbox);
    }

    /// Completed request records (drained), in completion order.
    pub fn take_completed(&mut self) -> Vec<RequestRecord> {
        self.completed.take_all()
    }

    /// Append completed request records to `out` (streaming-aggregation
    /// variant: drained incrementally, the slab keeps its capacity and
    /// retires the drained prefix).
    pub fn drain_completed_into(&mut self, out: &mut Vec<RequestRecord>) {
        self.completed.drain_into(out);
    }

    /// Requests dropped by admission control (drained).
    pub fn take_dropped(&mut self) -> Vec<DropRecord> {
        std::mem::take(&mut self.dropped)
    }

    /// Append drop records to `out` (streaming-aggregation variant).
    pub fn drain_dropped_into(&mut self, out: &mut Vec<DropRecord>) {
        out.append(&mut self.dropped);
    }

    /// Total drops recorded over the engine's lifetime (monotone — NOT
    /// reduced by `take_dropped`/`drain_dropped_into`, so closed-loop
    /// drivers can diff before/after snapshots even while a streaming
    /// backend drains the record buffer).
    pub fn dropped_count(&self) -> usize {
        self.drops_total as usize
    }

    /// Completed swap records (drained).
    pub fn take_swap_records(&mut self) -> Vec<SwapRecord> {
        std::mem::take(&mut self.swap_records)
    }

    /// Append completed swap records to `out` (streaming-aggregation
    /// variant).
    pub fn drain_swap_records_into(&mut self, out: &mut Vec<SwapRecord>) {
        out.append(&mut self.swap_records);
    }

    pub fn swap_stats(&self) -> SwapStats {
        self.swap.stats()
    }

    pub fn residency(&self, model: ModelId) -> Residency {
        self.swap.state(model)
    }

    pub fn queued(&self, model: ModelId) -> usize {
        self.queues.len(model)
    }

    pub fn inflight_batches(&self) -> usize {
        self.inflight_batches.len()
    }

    /// True when nothing is queued or in flight (quiescent).
    pub fn idle(&self) -> bool {
        self.queues.is_empty() && self.inflight_batches.is_empty() && self.inflight_loads.is_empty()
    }

    // ----- scheduling core -----

    /// Shed queued heads whose deadline became provably infeasible while
    /// they waited (no-op for non-shedding schedulers). Only heads need
    /// checking: under a per-model SLO deeper requests have later
    /// deadlines, so they are never *more* infeasible than their head.
    fn shed_stale_heads(&mut self, now: f64) {
        if !self.scheduler.sheds() {
            return;
        }
        let ctx = self.sched_ctx(now);
        for model in 0..self.queues.num_models() {
            if self.queues.len(model) == 0 {
                continue;
            }
            let cost = self.model_cost(model);
            while let Some(arrival) = self.queues.head(model).map(|r| r.arrival) {
                let deadline = self.deadline_for(model, arrival);
                let residency = self.swap.state(model);
                if !self.scheduler.drop_queued(&ctx, cost, deadline, residency) {
                    break;
                }
                let req = self.queues.pop_head(model).unwrap();
                self.drops_total += 1;
                self.completed.retire(req.id);
                self.dropped.push(DropRecord {
                    id: req.id,
                    model,
                    arrival: req.arrival,
                    deadline,
                    dropped_at: now,
                    residency,
                    group: 0,
                    reason: DropReason::Infeasible,
                });
            }
        }
    }

    /// Drain every schedulable queue, visiting models in the order the
    /// configured `Scheduler` ranks them (the default `fcfs` discipline
    /// is the paper's strict oldest-queue-head order). Two rules beyond
    /// the paper's prose, shared by every discipline:
    ///
    /// - a model whose swap-in is **Blocked** (every potential victim has
    ///   in-flight batches) stalls all *lower-priority* queues — otherwise
    ///   a hot model could be re-batched forever and the blocked model's
    ///   victim would never drain (starvation under skewed rates, which
    ///   §5.2 shows Computron tolerates);
    /// - models that are merely **Loading** do NOT stall lower-priority
    ///   queues — that concurrency is the entire point of the async
    ///   load-entry design (§3.2, Fig 4).
    ///
    /// The stall only shields queues the discipline ranks *below* the
    /// blocked model, so its starvation-freedom guarantee is only as
    /// strong as the rank key's aging. Under `fcfs` and `swap-aware` the
    /// key grows with arrival time, so a blocked model eventually
    /// outranks all fresh traffic and stalls it until its victim drains.
    /// Under `edf` a model with a much looser (or absent) SLO can be
    /// starved for as long as tighter-deadline queues stay saturated —
    /// the textbook EDF overload behaviour, documented in DESIGN.md §5;
    /// pair `edf` with `shed`-style admission or finite SLOs on every
    /// model when starvation matters.
    fn pump(&mut self, now: f64) {
        let mut candidates = std::mem::take(&mut self.cand_buf);
        loop {
            let mut progressed = false;
            self.shed_stale_heads(now);
            // Snapshot of models with queued work, ranked by the
            // scheduling discipline (fcfs: oldest head first). The
            // snapshot reuses the `cand_buf` scratch allocation — this
            // runs once per scheduling round, so it must not allocate.
            let ctx = self.sched_ctx(now);
            candidates.clear();
            for m in self.queues.nonempty_iter() {
                let head_arrival = self.queues.head_arrival(m).unwrap();
                candidates.push(Candidate {
                    model: m,
                    head_arrival,
                    head_deadline: self.deadline_for(m, head_arrival),
                    queue_len: self.queues.len(m),
                    residency: self.swap.state(m),
                    inflight: self.inflight_per_model[m],
                    cost: self.model_cost(m),
                    weight: self.weights[m],
                });
            }
            self.scheduler.order(&ctx, &mut candidates);
            'scan: for c in &candidates {
                let model = c.model;
                match self.swap.state(model) {
                    Residency::Resident => {
                        if self.inflight_per_model[model] < self.max_inflight_per_model {
                            self.submit_batch(now, model);
                            progressed = true;
                            // Queue head changed; re-sort on the next loop.
                            break 'scan;
                        }
                        // At its in-flight limit: its queue waits, younger
                        // queues may proceed.
                    }
                    Residency::Loading | Residency::PartiallyResident { .. } => {
                        // Chunked pipeline: batches may chase an in-flight
                        // load — workers gate each layer's compute on its
                        // chunk's arrival, so the transfer hides behind
                        // execution (time-to-first-chunk, DESIGN.md §6).
                        // Monolithic designs gate batches until Resident.
                        if self.chunked_active(model)
                            && !self.cancelling[model]
                            && self.inflight_per_model[model] < self.max_inflight_per_model
                        {
                            self.submit_batch(now, model);
                            progressed = true;
                            break 'scan;
                        }
                    }
                    Residency::Offloading => {
                        // Draining; must complete before a reload can start.
                    }
                    Residency::Offloaded => {
                        self.recompute_protected();
                        let inflight = &self.inflight_per_model;
                        // Delta swapping (DESIGN.md §12): never evict a
                        // protected base, and never let a variant evict
                        // its own base to admit itself.
                        let prot = &self.protected_buf;
                        let has_bases = self.has_bases;
                        let own_base = if has_bases { self.bases[model] } else { None };
                        // The broadcast strawman (Fig 2) has no safe-victim
                        // tracking at all — that is precisely why it
                        // violates load dependencies; the pipelined designs
                        // exclude models with in-flight batches.
                        let broadcast = self.cfg.load_design == crate::config::LoadDesign::Broadcast;
                        // §6 extension: predictive replacement — prefer not
                        // to evict the model predicted to be needed next.
                        let avoid = if self.cfg.prefetch {
                            self.predictor.predict_after(model)
                        } else {
                            None
                        };
                        let mut plan = self.swap.plan_swap_in(model, now, |m| {
                            (broadcast || inflight[m] == 0)
                                && Some(m) != avoid
                                && (!has_bases || (!prot[m] && Some(m) != own_base))
                        });
                        if plan == SwapPlan::Blocked && avoid.is_some() {
                            // Soft preference only: fall back to the plain
                            // filter rather than stalling.
                            plan = self.swap.plan_swap_in(model, now, |m| {
                                (broadcast || inflight[m] == 0)
                                    && (!has_bases || (!prot[m] && Some(m) != own_base))
                            });
                        }
                        match plan {
                            SwapPlan::Start { victim } => {
                                self.submit_swap(now, model, victim);
                                progressed = true;
                                break 'scan;
                            }
                            SwapPlan::Blocked => {
                                // Head-of-line: stop scheduling younger
                                // queues so a victim can drain. The chunked
                                // pipeline can additionally preempt a stale
                                // half-loaded model to free the slot.
                                if self.cfg.load_design
                                    == crate::config::LoadDesign::ChunkedPipelined
                                {
                                    self.try_cancel_stale_load(model);
                                }
                                break 'scan;
                            }
                            SwapPlan::AlreadyResident | SwapPlan::AlreadyLoading => {}
                        }
                    }
                }
            }
            if !progressed {
                break;
            }
        }
        candidates.clear();
        self.cand_buf = candidates;
    }

    fn submit_batch(&mut self, now: f64, model: ModelId) {
        debug_assert!(
            self.swap.is_resident(model)
                || (self.chunked_active(model) && self.swap.state(model).is_loading()),
            "load dependency violated"
        );
        let requests = self.queues.pop_batch(model, self.cfg.max_batch_size);
        debug_assert!(!requests.is_empty());
        let id = self.next_entry;
        self.next_entry += 1;
        let entry = BatchEntry::new(id, model, requests);
        self.swap.note_access(model, now);
        self.inflight_per_model[model] += 1;
        self.batch_submit_times.insert(id, now);
        self.inflight_batches.insert(id, entry.clone());
        self.outbox.push(Entry::Batch(entry));
    }

    fn submit_swap(&mut self, now: f64, model: ModelId, victim: Option<ModelId>) {
        let chunks = if self.chunked_active(model) { self.chunks_per_load[model] } else { 1 };
        let pair_idx = self.swap_pairs.len();
        self.swap_pairs.push(SwapPair {
            load_model: model,
            victim,
            submitted: now,
            outstanding: if victim.is_some() { 2 } else { 1 },
            completed: None,
            total_chunks: chunks,
            first_chunk_at: None,
            overlapped_chunks: 0,
            cancelled: false,
            tier: SwapTier::HostHit,
            bytes_override: None,
            delta_saved: 0,
        });
        // Offload first (paper measures swap from offload submission), then
        // the load immediately after — the backend overlaps them.
        if let Some(v) = victim {
            let id = self.next_entry;
            self.next_entry += 1;
            self.inflight_loads.insert(
                id,
                InflightLoad {
                    model: v,
                    dir: LoadDirection::Offload,
                    acks_remaining: self.world,
                    pair: pair_idx,
                    chunk_acks: Vec::new(),
                    cancelled: false,
                    target: None,
                },
            );
            self.outbox.push(Entry::Load(LoadEntry { id, model: v, dir: LoadDirection::Offload }));
        }
        let id = self.next_entry;
        self.next_entry += 1;
        self.inflight_loads.insert(
            id,
            InflightLoad {
                model,
                dir: LoadDirection::Load,
                acks_remaining: self.world,
                pair: pair_idx,
                chunk_acks: vec![0; chunks - 1],
                cancelled: false,
                target: None,
            },
        );
        self.outbox.push(Entry::Load(LoadEntry { id, model, dir: LoadDirection::Load }));
    }

    /// Abort model `model`'s in-flight chunked load: emit a cancel entry
    /// that makes every worker stop dispatching further chunks and
    /// discard the ones already on GPU (the pinned host copy stays the
    /// source of truth). Legal only under the chunked pipeline, for a
    /// model that is Loading/PartiallyResident with no in-flight batches
    /// — cancelling a model whose batch entries are already in the pipes
    /// would violate the load dependency. Returns true iff a cancel
    /// entry was issued; the swap slot frees when every worker acks.
    pub fn cancel_swap_in(&mut self, model: ModelId) -> bool {
        if !self.chunked_active(model)
            || self.cancelling[model]
            || !self.swap.state(model).is_loading()
            || self.inflight_per_model[model] != 0
        {
            return false;
        }
        let found = self
            .inflight_loads
            .iter()
            .find(|(_, l)| l.model == model && l.dir == LoadDirection::Load && !l.cancelled)
            .map(|(&id, l)| (id, l.pair));
        let Some((load_id, pair)) = found else { return false };
        self.inflight_loads.get_mut(&load_id).unwrap().cancelled = true;
        let id = self.next_entry;
        self.next_entry += 1;
        self.inflight_loads.insert(
            id,
            InflightLoad {
                model,
                dir: LoadDirection::Cancel,
                acks_remaining: self.world,
                pair,
                chunk_acks: Vec::new(),
                cancelled: false,
                target: Some(load_id),
            },
        );
        self.cancelling[model] = true;
        self.outbox.push(Entry::Load(LoadEntry { id, model, dir: LoadDirection::Cancel }));
        true
    }

    /// The hosting group died (fault injection, DESIGN.md §11): harvest
    /// every request that had not completed — queued ones first (model
    /// order, FIFO within each model), then the members of in-flight
    /// batches in entry-id order — and reset all transfer state so the
    /// caller can retry or drop them. Unsettled swap pairs are recorded
    /// as cancelled at `now`, every in-flight load is accounted as
    /// cancelled in `SwapStats` (offloads as completed — the data was
    /// headed to host memory), and all residency flips to `Offloaded`:
    /// the GPUs lost their memory. Completed/drop/swap records, counters,
    /// and the predictor's learned transitions survive — they describe
    /// the past, not the hardware. The engine is `idle()` afterwards and
    /// serves again as soon as the backend feeds it arrivals (recovery).
    pub fn fail(&mut self, now: f64) -> Vec<Request> {
        let mut harvested = Vec::new();
        for model in 0..self.queues.num_models() {
            while let Some(req) = self.queues.pop_head(model) {
                harvested.push(req);
            }
        }
        // HashMap iteration order is nondeterministic; sort by entry id
        // (== submission order) so retries replay identically run-to-run.
        let mut batch_ids: Vec<EntryId> = self.inflight_batches.keys().copied().collect();
        batch_ids.sort_unstable();
        for id in batch_ids {
            let batch = self.inflight_batches.remove(&id).unwrap();
            harvested.extend(batch.requests.iter().cloned());
        }
        self.batch_submit_times.clear();
        self.inflight_per_model.iter_mut().for_each(|n| *n = 0);
        self.inflight_loads.clear();
        for idx in 0..self.swap_pairs.len() {
            let pair = &mut self.swap_pairs[idx];
            if pair.completed.is_some() {
                continue;
            }
            pair.completed = Some(now);
            pair.cancelled = true;
            pair.outstanding = 0;
            let (load_model, victim, submitted) = (pair.load_model, pair.victim, pair.submitted);
            let ttfc = pair.first_chunk_at.unwrap_or(now) - submitted;
            let overlap = pair.overlapped_chunks as f64 / pair.total_chunks as f64;
            let (tier, bytes_override, delta_saved) =
                (pair.tier, pair.bytes_override, pair.delta_saved);
            self.swap_records.push(SwapRecord {
                load_model,
                victim,
                submitted,
                completed: now,
                time_to_first_chunk: ttfc,
                overlap_fraction: overlap,
                cancelled: true,
                bytes: bytes_override.unwrap_or(self.costs[load_model].bytes),
                tier,
                delta_bytes_saved: delta_saved,
                group: 0,
            });
        }
        self.cancelling.iter_mut().for_each(|c| *c = false);
        self.outbox.clear();
        self.swap.fail_all();
        // Harvested requests never complete in this engine (retries get
        // fresh ids): retire their record slots.
        for req in &harvested {
            self.completed.retire(req.id);
        }
        harvested
    }

    /// A burst flipped priorities while `requested`'s swap-in is Blocked:
    /// reclaim the cap slot from a stale in-flight load — one with no
    /// queued requests and no in-flight batches (in practice a
    /// speculative prefetch made obsolete by the burst).
    fn try_cancel_stale_load(&mut self, requested: ModelId) {
        let stale = (0..self.cancelling.len()).find(|&m| {
            m != requested
                && self.swap.state(m).is_loading()
                && !self.cancelling[m]
                && self.inflight_per_model[m] == 0
                && self.queues.len(m) == 0
        });
        if let Some(m) = stale {
            self.cancel_swap_in(m);
        }
    }
}

/// Convenience constructor used by tests and simple setups.
pub fn engine_for(num_models: usize, tp: usize, pp: usize, cfg: EngineConfig) -> Engine {
    Engine::new(num_models, tp * pp, pp, cfg, 0x5EED)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PolicyKind;

    fn cfg(cap: usize, max_batch: usize) -> EngineConfig {
        EngineConfig {
            max_batch_size: max_batch,
            resident_cap: cap,
            policy: PolicyKind::Lru,
            load_design: crate::config::LoadDesign::AsyncPipelined,
            prefetch: false,
            scheduler: crate::config::SchedulerKind::Fcfs,
            chunk_layers: None,
            prefetch_min_count: 2,
        }
    }

    /// Chunked-pipeline engine: `chunks` chunks per load entry.
    fn chunked_engine(models: usize, cap: usize, max_batch: usize, chunks: usize) -> Engine {
        let mut e = engine_for(
            models,
            1,
            1,
            EngineConfig {
                load_design: crate::config::LoadDesign::ChunkedPipelined,
                ..cfg(cap, max_batch)
            },
        );
        e.set_chunks_per_load(vec![chunks; models]);
        e
    }

    /// Ack a load entry from all `world` workers.
    fn ack_all(e: &mut Engine, now: f64, id: EntryId, world: usize) {
        for _ in 0..world {
            e.on_load_ack(now, id);
        }
    }

    #[test]
    fn request_to_offloaded_model_triggers_load_then_batch() {
        let mut e = engine_for(2, 2, 2, cfg(1, 8));
        e.on_request(0.0, 0, 8);
        let out = e.drain_outbox();
        // No victim (cap not reached): just a load entry.
        assert_eq!(out.len(), 1);
        let load_id = match &out[0] {
            Entry::Load(l) => {
                assert_eq!(l.model, 0);
                assert_eq!(l.dir, LoadDirection::Load);
                l.id
            }
            _ => panic!("expected load entry"),
        };
        // Batch must NOT be submitted until all 4 workers ack.
        for _ in 0..3 {
            e.on_load_ack(1.0, load_id);
            assert!(e.drain_outbox().is_empty(), "batch submitted before load complete");
        }
        e.on_load_ack(1.0, load_id);
        let out = e.drain_outbox();
        assert_eq!(out.len(), 1);
        match &out[0] {
            Entry::Batch(b) => {
                assert_eq!(b.model, 0);
                assert_eq!(b.batch_size(), 1);
            }
            _ => panic!("expected batch entry"),
        }
    }

    #[test]
    fn swap_emits_offload_then_load() {
        let mut e = engine_for(2, 1, 1, cfg(1, 8));
        e.force_resident(0, 0.0);
        e.on_request(1.0, 1, 8);
        let out = e.drain_outbox();
        assert_eq!(out.len(), 2);
        match (&out[0], &out[1]) {
            (Entry::Load(off), Entry::Load(load)) => {
                assert_eq!(off.model, 0);
                assert_eq!(off.dir, LoadDirection::Offload);
                assert_eq!(load.model, 1);
                assert_eq!(load.dir, LoadDirection::Load);
            }
            _ => panic!("expected offload+load pair"),
        }
    }

    #[test]
    fn swap_record_measures_pair() {
        let mut e = engine_for(2, 1, 1, cfg(1, 8));
        e.force_resident(0, 0.0);
        e.on_request(1.0, 1, 8);
        let out = e.drain_outbox();
        let (off_id, load_id) = (out[0].id(), out[1].id());
        e.on_load_ack(1.5, off_id); // offload done first
        assert!(e.take_swap_records().is_empty());
        e.on_load_ack(2.0, load_id);
        let recs = e.take_swap_records();
        assert_eq!(recs.len(), 1);
        assert_eq!(recs[0].load_model, 1);
        assert_eq!(recs[0].victim, Some(0));
        assert_eq!(recs[0].submitted, 1.0);
        assert_eq!(recs[0].completed, 2.0);
        assert!((recs[0].duration() - 1.0).abs() < 1e-12);
        // Monolithic load: the whole shard is the first chunk, batches
        // never overlapped it, nothing was cancelled.
        assert!((recs[0].time_to_first_chunk - 1.0).abs() < 1e-12);
        assert_eq!(recs[0].overlap_fraction, 0.0);
        assert!(!recs[0].cancelled);
    }

    #[test]
    fn chunked_engine_submits_batch_while_loading() {
        // The tentpole behaviour: under the chunked pipeline the batch
        // entry follows the load entry into the pipes immediately, so
        // compute can chase the chunks instead of waiting for residency.
        let mut e = chunked_engine(2, 1, 8, 4);
        e.force_resident(0, 0.0);
        e.on_request(1.0, 1, 8);
        let out = e.drain_outbox();
        assert_eq!(out.len(), 3, "offload + load + early batch, got {out:?}");
        assert!(out[0].is_load() && out[1].is_load());
        match &out[2] {
            Entry::Batch(b) => assert_eq!(b.model, 1),
            _ => panic!("expected early batch, got {:?}", out[2]),
        }
        assert!(e.residency(1).is_loading());
        // The async engine gates the same batch until the load acks.
        let mut e = engine_for(2, 1, 1, cfg(1, 8));
        e.force_resident(0, 0.0);
        e.on_request(1.0, 1, 8);
        assert_eq!(e.drain_outbox().len(), 2, "monolithic: no early batch");
    }

    #[test]
    fn chunk_acks_advance_partial_residency_and_ttfc() {
        let mut e = chunked_engine(2, 1, 8, 4);
        e.on_request(0.0, 0, 8);
        let out = e.drain_outbox();
        // No victim: load + early batch.
        let load_id = out[0].id();
        let batch_id = out[1].id();
        assert_eq!(e.residency(0), Residency::Loading);
        e.on_chunk_ack(0.5, load_id, 0);
        assert_eq!(e.residency(0), Residency::PartiallyResident { loaded: 1, total: 4 });
        e.on_chunk_ack(0.7, load_id, 1);
        e.on_chunk_ack(0.9, load_id, 2);
        assert_eq!(e.residency(0), Residency::PartiallyResident { loaded: 3, total: 4 });
        e.on_load_ack(1.1, load_id);
        assert_eq!(e.residency(0), Residency::Resident);
        let recs = e.take_swap_records();
        assert_eq!(recs.len(), 1);
        assert!((recs[0].time_to_first_chunk - 0.5).abs() < 1e-12);
        // All 4 chunks landed while the early batch was in flight.
        assert!((recs[0].overlap_fraction - 1.0).abs() < 1e-12);
        assert!(!recs[0].cancelled);
        e.on_batch_done(1.5, batch_id);
        assert_eq!(e.take_completed().len(), 1);
        assert!(e.idle());
    }

    #[test]
    fn cancellation_mid_transfer_resolves_cleanly() {
        // Model 0 resident+idle, cap 1. A request for model 1 starts a
        // swap (victim 0) and an early batch; once that batch completes
        // and model 0 is requested again, the engine is Blocked (model 0
        // still Offloading) — then, when the drain finishes but model 1
        // is a stale half-loaded model with no demand, the blocked pump
        // cancels it mid-transfer and reclaims the slot.
        let mut e = chunked_engine(2, 1, 8, 4);
        e.force_resident(0, 0.0);
        e.on_request(1.0, 1, 8);
        let out = e.drain_outbox();
        assert_eq!(out.len(), 3);
        let (off0, load1, batch1) = (out[0].id(), out[1].id(), out[2].id());
        e.on_chunk_ack(1.2, load1, 0);
        assert_eq!(e.residency(1), Residency::PartiallyResident { loaded: 1, total: 4 });
        // The early batch completes; model 1 now has no queued work and
        // no in-flight batches, but still holds the cap slot.
        e.on_batch_done(1.5, batch1);
        assert_eq!(e.take_completed().len(), 1);
        // Demand flips back to model 0: it is still Offloading, so the
        // request just queues.
        e.on_request(2.0, 0, 8);
        assert!(e.drain_outbox().is_empty());
        // The drain completes: model 0's swap-in is now Blocked (the cap
        // slot is held by stale half-loaded model 1), so the pump
        // preempts model 1 with a cancel entry.
        e.on_load_ack(2.5, off0);
        let out = e.drain_outbox();
        assert_eq!(out.len(), 1, "expected a cancel entry, got {out:?}");
        let cancel1 = match &out[0] {
            Entry::Load(l) => {
                assert_eq!(l.model, 1);
                assert_eq!(l.dir, LoadDirection::Cancel);
                l.id
            }
            _ => panic!("expected cancel entry"),
        };
        // Chunk acks racing the cancel are tolerated and ignored.
        e.on_chunk_ack(2.6, load1, 1);
        assert_eq!(e.residency(1), Residency::PartiallyResident { loaded: 1, total: 4 });
        // Cancel acks: slot frees, model 1 ends Offloaded, and model 0's
        // queued request immediately starts a fresh swap-in + early batch.
        e.on_load_ack(3.0, cancel1);
        assert_eq!(e.residency(1), Residency::Offloaded);
        let out = e.drain_outbox();
        assert_eq!(out.len(), 2, "load + early batch for model 0, got {out:?}");
        assert!(out[0].is_load());
        assert_eq!(out[0].model(), 0);
        // The cancelled pair is recorded as such.
        let recs = e.take_swap_records();
        assert_eq!(recs.len(), 1);
        assert!(recs[0].cancelled);
        assert_eq!(recs[0].load_model, 1);
        assert_eq!(recs[0].victim, Some(0));
        assert_eq!(recs[0].completed, 3.0);
        assert!((recs[0].time_to_first_chunk - 0.2).abs() < 1e-12);
        // Drain model 0's fresh load to quiescence and check accounting.
        e.on_load_ack(3.5, out[0].id());
        let batch = e.drain_outbox();
        assert!(batch.is_empty(), "early batch was already submitted: {batch:?}");
        e.on_batch_done(4.0, out[1].id());
        assert_eq!(e.take_completed().len(), 1);
        assert!(e.idle());
        let stats = e.swap_stats();
        assert_eq!(stats.loads_cancelled, 1);
        assert_eq!(stats.loads_started, stats.loads_completed + stats.loads_cancelled);
    }

    #[test]
    fn batching_packs_up_to_max() {
        let mut e = engine_for(1, 1, 1, cfg(1, 4));
        e.force_resident(0, 0.0);
        // First request goes out alone (nothing else queued).
        e.on_request(0.0, 0, 8);
        let out = e.drain_outbox();
        assert_eq!(out.len(), 1);
        let first = out[0].id();
        // While the first batch is in flight (inflight limit pp=1), more
        // requests accumulate.
        for i in 0..6 {
            e.on_request(0.1 * (i + 1) as f64, 0, 8);
        }
        assert!(e.drain_outbox().is_empty(), "limit should hold batches back");
        // Completion frees the slot: next batch packs max_batch=4.
        e.on_batch_done(1.0, first);
        let out = e.drain_outbox();
        assert_eq!(out.len(), 1);
        match &out[0] {
            Entry::Batch(b) => assert_eq!(b.batch_size(), 4),
            _ => panic!(),
        }
        // Two requests remain queued.
        assert_eq!(e.queued(0), 2);
    }

    #[test]
    fn oldest_head_served_when_choice_exists() {
        // One pump with a genuine choice: model 0 becomes resident via a
        // load ack while BOTH models 0 and 1 have queued requests; model
        // 1's head is older and model 1 is already resident with a free
        // slot — the engine must submit model 1's batch first.
        let mut e = engine_for(2, 1, 1, cfg(2, 8));
        e.force_resident(1, 0.0);
        e.set_max_inflight_per_model(1);
        // Occupy model 1 so its later request queues.
        e.on_request(0.0, 1, 8);
        let busy1 = e.drain_outbox()[0].id();
        // Request model 0 (offloaded) -> load entry; request model 1 queues.
        e.on_request(1.0, 0, 8);
        let load0 = e.drain_outbox()[0].id();
        e.on_request(2.0, 1, 8);
        assert!(e.drain_outbox().is_empty());
        // Free model 1 while model 0 still loading: model 1's (older) head
        // is served.
        e.on_batch_done(3.0, busy1);
        let out = e.drain_outbox();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].model(), 1);
        // Now the load ack makes model 0 resident: model 0's request (the
        // only remaining queued one) goes out.
        e.on_load_ack(4.0, load0);
        let out = e.drain_outbox();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].model(), 0);
    }

    #[test]
    fn blocked_swap_stalls_younger_queues_until_victim_drains() {
        // Starvation guard: model 0 (resident, hot) is busy; model 1's
        // swap-in is blocked because model 0 is the only victim. A younger
        // request for model 0 must NOT be submitted when model 0's batch
        // completes — the engine holds it back so model 0 drains and the
        // swap can start.
        let mut e = engine_for(2, 1, 1, cfg(1, 8));
        e.force_resident(0, 0.0);
        e.on_request(0.0, 0, 8);
        let batch0 = e.drain_outbox()[0].id();
        e.on_request(1.0, 1, 8); // older head for model 1, blocked
        e.on_request(2.0, 0, 8); // younger request for the hot model
        assert!(e.drain_outbox().is_empty());
        e.on_batch_done(3.0, batch0);
        let out = e.drain_outbox();
        // The swap for model 1 must start; model 0's younger request must
        // still be queued (not batched).
        assert_eq!(out.len(), 2, "expected offload+load, got {out:?}");
        assert!(out.iter().all(Entry::is_load));
        assert_eq!(e.queued(0), 1);
    }

    #[test]
    fn model_with_inflight_batches_not_evicted() {
        let mut e = engine_for(3, 1, 1, cfg(2, 8));
        e.force_resident(0, 0.0);
        e.force_resident(1, 0.0);
        // Model 0 has an in-flight batch (and was used LEAST recently, so
        // plain LRU would pick it).
        e.on_request(0.0, 0, 8);
        let out = e.drain_outbox();
        assert_eq!(out.len(), 1);
        e.on_request(0.5, 1, 8); // bumps model 1 recency AND occupies it? no: completes below
        let out1 = e.drain_outbox();
        e.on_batch_done(0.6, out1[0].id()); // model 1 now idle but recent
        // Request model 2: must evict model 1 (idle) not model 0 (in flight),
        // even though 0 is older by LRU.
        e.on_request(1.0, 2, 8);
        let out = e.drain_outbox();
        let offload = out.iter().find_map(|en| match en {
            Entry::Load(l) if l.dir == LoadDirection::Offload => Some(l.model),
            _ => None,
        });
        assert_eq!(offload, Some(1));
    }

    #[test]
    fn blocked_swap_retries_after_completion() {
        let mut e = engine_for(2, 1, 1, cfg(1, 8));
        e.force_resident(0, 0.0);
        // Model 0 busy with a batch; request for model 1 cannot evict.
        e.on_request(0.0, 0, 8);
        let batch0 = e.drain_outbox()[0].id();
        e.on_request(0.5, 1, 8);
        assert!(e.drain_outbox().is_empty(), "no eviction while victim busy");
        // Batch completes → pump retries the swap.
        e.on_batch_done(1.0, batch0);
        let out = e.drain_outbox();
        assert_eq!(out.len(), 2, "offload+load after unblock");
        assert_eq!(out[0].model(), 0);
        assert_eq!(out[1].model(), 1);
    }

    #[test]
    fn request_records_complete_lifecycle() {
        let mut e = engine_for(1, 2, 1, cfg(1, 8));
        e.on_request(0.0, 0, 4);
        let load_id = e.drain_outbox()[0].id();
        ack_all(&mut e, 2.0, load_id, 2);
        let batch_id = e.drain_outbox()[0].id();
        e.on_batch_done(3.5, batch_id);
        let recs = e.take_completed();
        assert_eq!(recs.len(), 1);
        let r = &recs[0];
        assert_eq!(r.model, 0);
        assert_eq!(r.arrival, 0.0);
        assert_eq!(r.batch_submit, 2.0);
        assert_eq!(r.done, 3.5);
        assert!((r.latency() - 3.5).abs() < 1e-12);
        assert!((r.queue_time() - 2.0).abs() < 1e-12);
        assert!(e.idle());
    }

    #[test]
    fn alternating_worst_case_swaps_every_request() {
        // §5.1's worst case: cap 1, alternating blocking requests.
        let mut e = engine_for(2, 1, 1, cfg(1, 1));
        e.force_resident(0, 0.0);
        let mut now = 0.0;
        let mut swaps = 0;
        for i in 0..6 {
            let model = 1 - (i % 2); // start with model 1 (0 resident)
            e.on_request(now, model, 2);
            let out = e.drain_outbox();
            // Expect offload+load then (after acks) a batch.
            assert_eq!(out.len(), 2, "iteration {i}");
            swaps += 1;
            now += 1.0;
            e.on_load_ack(now, out[0].id());
            e.on_load_ack(now, out[1].id());
            let batch = e.drain_outbox();
            assert_eq!(batch.len(), 1);
            now += 0.1;
            e.on_batch_done(now, batch[0].id());
        }
        assert_eq!(e.take_swap_records().len(), swaps);
        assert_eq!(e.swap_stats().loads_completed as usize, swaps);
    }

    fn cfg_with_scheduler(cap: usize, max_batch: usize, s: crate::config::SchedulerKind) -> EngineConfig {
        EngineConfig { scheduler: s, ..cfg(cap, max_batch) }
    }

    #[test]
    fn records_carry_deadlines_and_attainment() {
        let mut e = engine_for(2, 1, 1, cfg(2, 8));
        e.set_slos(&[1.0, f64::INFINITY]);
        e.force_resident(0, 0.0);
        e.force_resident(1, 0.0);
        e.on_request(0.0, 0, 4);
        e.on_request(0.0, 1, 4);
        let out = e.drain_outbox();
        assert_eq!(out.len(), 2);
        // Model 0 finishes past its 1 s SLO; model 1 has no deadline.
        e.on_batch_done(2.0, out[0].id());
        e.on_batch_done(2.0, out[1].id());
        let recs = e.take_completed();
        let r0 = recs.iter().find(|r| r.model == 0).unwrap();
        let r1 = recs.iter().find(|r| r.model == 1).unwrap();
        assert_eq!(r0.deadline, 1.0);
        assert!(!r0.attained());
        assert_eq!(r1.deadline, f64::INFINITY);
        assert!(r1.attained());
    }

    /// Build the one genuine choice point the engine has: cap 1, model 0
    /// resident and busy, model 1's (older) swap-in blocked behind it,
    /// plus a younger queued request for model 0. When model 0's batch
    /// completes, the scheduler decides between re-batching model 0 and
    /// starting model 1's swap. Returns the entries emitted at that pump.
    fn choice_point(kind: crate::config::SchedulerKind, slos: &[f64], cost: f64) -> Vec<Entry> {
        let mut e = engine_for(2, 1, 1, cfg_with_scheduler(1, 8, kind));
        e.set_slos(slos);
        e.set_uniform_cost_model(cost, 0.0, 0.0);
        e.force_resident(0, 0.0);
        e.on_request(0.0, 0, 4);
        let busy = e.drain_outbox()[0].id();
        e.on_request(0.1, 1, 4); // older head, needs a swap (blocked)
        e.on_request(0.2, 0, 4); // younger head for the warm model
        assert!(e.drain_outbox().is_empty());
        e.on_batch_done(0.5, busy);
        e.drain_outbox()
    }

    #[test]
    fn edf_serves_tighter_deadline_first() {
        use crate::config::SchedulerKind;
        // Model 0's queued request has the tighter deadline (0.2 + 1.0)
        // vs model 1's (0.1 + 100.0): EDF re-batches model 0; FCFS starts
        // model 1's swap (older head).
        let edf = choice_point(SchedulerKind::Edf, &[1.0, 100.0], 0.0);
        assert_eq!(edf.len(), 1, "EDF emits one batch, got {edf:?}");
        assert!(!edf[0].is_load());
        assert_eq!(edf[0].model(), 0);

        let fcfs = choice_point(SchedulerKind::Fcfs, &[1.0, 100.0], 0.0);
        assert_eq!(fcfs.len(), 2, "FCFS starts the swap, got {fcfs:?}");
        assert!(fcfs.iter().all(Entry::is_load));

        // With equal SLOs the deadline order equals the arrival order:
        // EDF degenerates to FCFS.
        let edf_eq = choice_point(SchedulerKind::Edf, &[5.0, 5.0], 0.0);
        assert_eq!(edf_eq.len(), 2);
        assert!(edf_eq.iter().all(Entry::is_load));
    }

    #[test]
    fn swap_aware_defers_unamortized_swap() {
        use crate::config::SchedulerKind;
        // Swap cost 0.4 s amortized over model 1's single queued request
        // pushes its effective key past model 0's head: the warm model is
        // re-batched first.
        let out = choice_point(SchedulerKind::SwapAware, &[f64::INFINITY; 2], 0.4);
        assert_eq!(out.len(), 1, "swap-aware re-batches the warm model, got {out:?}");
        assert_eq!(out[0].model(), 0);
        // Zero swap cost: identical to FCFS (swap starts).
        let out = choice_point(SchedulerKind::SwapAware, &[f64::INFINITY; 2], 0.0);
        assert_eq!(out.len(), 2);
        assert!(out.iter().all(Entry::is_load));
    }

    #[test]
    fn shed_rejects_provably_infeasible_at_admission() {
        use crate::config::SchedulerKind;
        let mut e = engine_for(2, 1, 1, cfg_with_scheduler(1, 8, SchedulerKind::Shed));
        // Cold load lower bound 0.75 s, exec floor 0.03 s.
        e.set_uniform_cost_model(0.8, 0.75, 0.03);
        e.set_slos(&[0.5, 2.0]);
        e.force_resident(1, 0.0);
        // Model 0 is offloaded: 0.75 + 0.03 > 0.5 — provably infeasible.
        let id = e.on_request(0.0, 0, 4);
        assert!(e.drain_outbox().is_empty(), "rejected request must not schedule");
        let drops = e.take_dropped();
        assert_eq!(drops.len(), 1);
        assert_eq!(drops[0].id, id);
        assert_eq!(drops[0].model, 0);
        assert_eq!(drops[0].deadline, 0.5);
        assert_eq!(drops[0].dropped_at, 0.0);
        assert_eq!(drops[0].residency, Residency::Offloaded);
        // Model 1 is resident with a feasible SLO: admitted and served.
        e.on_request(0.0, 1, 4);
        assert_eq!(e.drain_outbox().len(), 1);
    }

    #[test]
    fn shed_drops_heads_that_go_stale_in_queue() {
        use crate::config::SchedulerKind;
        let mut e = engine_for(1, 1, 1, cfg_with_scheduler(1, 8, SchedulerKind::Shed));
        e.set_slos(&[0.5]);
        e.force_resident(0, 0.0);
        e.set_max_inflight_per_model(1);
        // First request goes out; second queues behind it (feasible now).
        e.on_request(0.0, 0, 4);
        let busy = e.drain_outbox()[0].id();
        e.on_request(0.1, 0, 4); // deadline 0.6
        assert!(e.drain_outbox().is_empty());
        assert_eq!(e.queued(0), 1);
        // The batch completes long after the queued deadline: the head is
        // shed instead of submitted.
        e.on_batch_done(1.0, busy);
        assert!(e.drain_outbox().is_empty(), "stale head must not be batched");
        let drops = e.take_dropped();
        assert_eq!(drops.len(), 1);
        assert_eq!(drops[0].deadline, 0.6);
        assert_eq!(drops[0].dropped_at, 1.0);
        assert_eq!(e.queued(0), 0);
        // The completed first request is still recorded normally.
        assert_eq!(e.take_completed().len(), 1);
    }

    #[test]
    fn shed_without_slos_never_drops() {
        use crate::config::SchedulerKind;
        let mut e = engine_for(2, 1, 1, cfg_with_scheduler(1, 4, SchedulerKind::Shed));
        e.set_uniform_cost_model(0.8, 0.75, 0.03);
        e.force_resident(0, 0.0);
        let mut now = 0.0;
        for i in 0..8 {
            e.on_request(now, i % 2, 4);
            now += 0.5;
            // Complete everything in flight to keep the run moving.
            for entry in e.drain_outbox() {
                match entry {
                    Entry::Batch(b) => e.on_batch_done(now, b.id),
                    Entry::Load(l) => e.on_load_ack(now, l.id),
                }
            }
        }
        assert!(e.take_dropped().is_empty(), "infinite SLOs are always feasible");
    }

    #[test]
    fn fail_harvests_queued_and_inflight_requests() {
        let mut e = engine_for(2, 2, 1, cfg(2, 2));
        e.force_resident(0, 0.0);
        // One batch in flight for model 0, two queued behind it, and one
        // queued for offloaded model 1 (its load goes out too).
        e.on_request(0.0, 0, 4);
        e.on_request(0.1, 0, 4);
        e.on_request(0.2, 0, 4);
        e.on_request(0.3, 1, 4);
        let out = e.drain_outbox();
        assert!(out.iter().any(|en| !en.is_load()), "a batch went out");
        assert!(out.iter().any(Entry::is_load), "model 1's load went out");
        let harvested = e.fail(1.0);
        // Queued requests come back first (model order), then in-flight
        // batch members in entry order.
        let ids: Vec<_> = harvested.iter().map(|r| r.id).collect();
        assert_eq!(harvested.len(), 4, "{harvested:?}");
        assert_eq!(ids, vec![1, 2, 3, 0]);
        assert!(e.idle(), "a failed engine is quiescent");
        assert!(e.drain_outbox().is_empty(), "outbox wiped");
        for m in 0..2 {
            assert_eq!(e.residency(m), Residency::Offloaded, "GPU memory lost");
        }
        // The in-flight swap pair settles as cancelled; SwapStats
        // invariants hold (loads started == completed + cancelled).
        let recs = e.take_swap_records();
        assert_eq!(recs.len(), 1);
        assert!(recs[0].cancelled);
        let s = e.swap_stats();
        assert_eq!(s.loads_started, s.loads_completed + s.loads_cancelled);
        assert_eq!(s.offloads_started, s.offloads_completed);
        // The engine serves again after recovery: same request replayed.
        e.on_request(2.0, 0, 4);
        let out = e.drain_outbox();
        assert!(out.iter().any(Entry::is_load), "cold reload after recovery");
    }

    #[test]
    fn fail_on_idle_engine_is_a_no_op_harvest() {
        let mut e = engine_for(2, 1, 1, cfg(1, 8));
        assert!(e.fail(0.5).is_empty());
        assert!(e.idle());
        assert!(e.take_swap_records().is_empty());
    }

    #[test]
    fn base_with_live_variant_is_never_the_victim() {
        // Models: 0 = base (resident, least recently used), 1 = its delta
        // variant (resident), 2 = standalone. Cap 2, so serving model 2
        // needs a victim. Plain LRU would evict the base (model 0); base
        // protection must divert the eviction to the variant instead.
        let mut e = engine_for(3, 1, 1, cfg(2, 8));
        e.set_bases(vec![None, Some(0), None]);
        e.force_resident(0, 0.0);
        e.force_resident(1, 1.0);
        e.on_request(2.0, 2, 8);
        let out = e.drain_outbox();
        assert_eq!(out.len(), 2, "offload + load, got {out:?}");
        match (&out[0], &out[1]) {
            (Entry::Load(off), Entry::Load(load)) => {
                assert_eq!(off.dir, LoadDirection::Offload);
                assert_eq!(off.model, 1, "variant evicted, base protected");
                assert_eq!(load.model, 2);
            }
            _ => panic!("expected offload+load pair"),
        }
        // Control: identical setup without lineage evicts the LRU base.
        let mut e = engine_for(3, 1, 1, cfg(2, 8));
        e.force_resident(0, 0.0);
        e.force_resident(1, 1.0);
        e.on_request(2.0, 2, 8);
        let out = e.drain_outbox();
        assert_eq!(out[0].model(), 0, "no lineage: plain LRU victim");
    }

    #[test]
    fn variant_never_evicts_its_own_base() {
        // Cap 1 holds only the base; its variant's swap-in would have to
        // evict the base it is about to read deltas against — Blocked.
        let mut e = engine_for(2, 1, 1, cfg(1, 8));
        e.set_bases(vec![None, Some(0)]);
        e.force_resident(0, 0.0);
        e.on_request(1.0, 1, 8);
        assert!(e.drain_outbox().is_empty(), "own-base eviction must block");
        // Control: without lineage the same request swaps the base out.
        let mut e = engine_for(2, 1, 1, cfg(1, 8));
        e.force_resident(0, 0.0);
        e.on_request(1.0, 1, 8);
        assert_eq!(e.drain_outbox().len(), 2);
    }

    #[test]
    fn annotate_load_stamps_tier_and_delta_bytes() {
        let mut e = engine_for(2, 1, 1, cfg(1, 8));
        e.set_cost_model(
            vec![ModelCost { swap_cost: 0.0, swap_floor: 0.0, bytes: 1000, chunked: false }; 2],
            0.0,
        );
        e.on_request(0.0, 0, 8);
        let load_id = e.drain_outbox()[0].id();
        e.annotate_load(load_id, SwapTier::NvmeMiss, Some(42), 7);
        e.on_load_ack(1.0, load_id);
        let recs = e.take_swap_records();
        assert_eq!(recs.len(), 1);
        assert_eq!(recs[0].tier, SwapTier::NvmeMiss);
        assert_eq!(recs[0].bytes, 42, "override replaces the cost-model shard");
        assert_eq!(recs[0].delta_bytes_saved, 7);
        // Un-annotated loads keep the defaults: HostHit + cost-model bytes.
        e.on_request(2.0, 1, 8);
        let out = e.drain_outbox();
        let load_id = out.last().unwrap().id();
        for en in &out[..out.len() - 1] {
            e.on_load_ack(2.5, en.id());
        }
        e.on_load_ack(3.0, load_id);
        let recs = e.take_swap_records();
        assert_eq!(recs.len(), 1);
        assert_eq!(recs[0].tier, SwapTier::HostHit);
        assert_eq!(recs[0].bytes, 1000);
        assert_eq!(recs[0].delta_bytes_saved, 0);
    }

    #[test]
    fn no_batch_for_nonresident_model_ever() {
        use crate::util::prop;
        use crate::util::rng::Rng;
        // Property: under random request/ack interleavings, every batch
        // entry in the outbox is for a currently-resident model at the
        // moment of submission (checked inside the engine via residency
        // queries right after drain).
        prop::check(
            "load-dependency",
            |rng: &mut Rng| {
                let models = prop::usize_in(rng, 2, 4);
                let cap = prop::usize_in(rng, 1, models);
                let reqs: Vec<usize> = (0..32).map(|_| rng.index(models)).collect();
                (models, cap, reqs)
            },
            |(models, cap, reqs)| {
                let world = 2;
                let mut e = Engine::new(
                    *models,
                    world,
                    1,
                    cfg(*cap, 4),
                    7,
                );
                let mut now = 0.0;
                let mut pending_loads: Vec<EntryId> = Vec::new();
                let mut pending_batches: Vec<EntryId> = Vec::new();
                for &m in reqs {
                    now += 0.1;
                    e.on_request(now, m, 8);
                    // Drain and validate.
                    for entry in e.drain_outbox() {
                        match entry {
                            Entry::Batch(b) => {
                                if e.residency(b.model) != Residency::Resident {
                                    return Err(format!(
                                        "batch for non-resident model {}",
                                        b.model
                                    ));
                                }
                                pending_batches.push(b.id);
                            }
                            Entry::Load(l) => pending_loads.push(l.id),
                        }
                    }
                    // Randomly complete some outstanding work.
                    if !pending_loads.is_empty() && now as u64 % 2 == 0 {
                        let id = pending_loads.remove(0);
                        now += 0.5;
                        for _ in 0..world {
                            e.on_load_ack(now, id);
                        }
                        for entry in e.drain_outbox() {
                            match entry {
                                Entry::Batch(b) => {
                                    if e.residency(b.model) != Residency::Resident {
                                        return Err("batch for non-resident".into());
                                    }
                                    pending_batches.push(b.id);
                                }
                                Entry::Load(l) => pending_loads.push(l.id),
                            }
                        }
                    }
                    if pending_batches.len() > 2 {
                        let id = pending_batches.remove(0);
                        now += 0.2;
                        e.on_batch_done(now, id);
                        for entry in e.drain_outbox() {
                            match entry {
                                Entry::Batch(b) => pending_batches.push(b.id),
                                Entry::Load(l) => pending_loads.push(l.id),
                            }
                        }
                    }
                }
                Ok(())
            },
        );
    }
}
