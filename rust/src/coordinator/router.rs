//! Cluster-level request routing across model-parallel groups
//! (DESIGN.md §8).
//!
//! With a multi-group [`crate::config::PlacementSpec`] a model can be
//! *replicated* — hosted by several engine groups at once — and every
//! arrival must first pick a group before the per-group scheduler
//! (`coordinator::scheduler`) ever sees it. AlpaServe (arXiv 2302.11665)
//! shows this placement/routing layer is where model-parallel
//! multiplexing pays off under real traffic, so the decision is lifted
//! into a `Router` trait behind a named registry (mirroring
//! `scheduler::by_name` and `scenarios::by_name`):
//!
//! | name                | discipline |
//! |---------------------|------------|
//! | `round-robin`       | per-model rotation over the model's replica groups |
//! | `least-loaded`      | lowest pending-work queue cost wins (ties by group id) |
//! | `resident-affinity` | prefer groups where the model is already warm; among cold groups, cheapest swap wins |
//!
//! The backend (`sim::SimCluster`) drives the trait at exactly one point:
//! when an arrival pops, it snapshots one [`GroupView`] per replica group
//! and asks the router for a destination. Everything after that — queues,
//! batching, swaps — is the unchanged per-group engine, which is what
//! keeps a single-group placement bit-for-bit identical to the
//! pre-placement system (pinned by `rust/tests/cluster_equiv.rs`).
//!
//! Routers must be deterministic: same views, same (internal) state, same
//! answer — runs stay reproducible bit-for-bit.

use crate::config::RouterKind;
use crate::coordinator::entry::ModelId;
use crate::coordinator::swap::Residency;

/// Snapshot of one candidate group for one routing decision.
#[derive(Clone, Copy, Debug)]
pub struct GroupView {
    /// Global group index.
    pub group: usize,
    /// Pending work at this group's engine: queued requests plus
    /// in-flight batch entries (the `least-loaded` key). Unitless but
    /// consistent across groups within one decision.
    pub queue_cost: f64,
    /// The routed model's residency on this group.
    pub residency: Residency,
    /// The routed model's swap-in cost estimate on this group (per-group
    /// cost model: its grid and link) — `resident-affinity`'s tiebreak
    /// among cold groups.
    pub swap_cost: f64,
}

impl GroupView {
    /// True when routing here does not require a new swap-in: the model
    /// is resident, partially resident, or already loading.
    pub fn warm(&self) -> bool {
        matches!(
            self.residency,
            Residency::Resident | Residency::PartiallyResident { .. } | Residency::Loading
        )
    }
}

/// A cluster routing discipline.
pub trait Router: Send {
    fn kind(&self) -> RouterKind;

    fn name(&self) -> &'static str {
        self.kind().name()
    }

    /// Pick the destination group for one arrival of `model` among its
    /// replica groups. `candidates` is non-empty and sorted by ascending
    /// group id; the returned value is the chosen `GroupView::group`.
    /// Must be deterministic given the views and the router's own state.
    fn route(&mut self, model: ModelId, candidates: &[GroupView]) -> usize;
}

/// `round-robin` — rotate each model over its replica groups in group-id
/// order. Blind to load and residency, but perfectly fair: over any K
/// consecutive arrivals of one model, per-group counts differ by at most
/// one (pinned by `rust/tests/router_prop.rs`).
pub struct RoundRobin {
    /// Per-model rotation cursor, grown lazily.
    counters: Vec<u64>,
}

impl RoundRobin {
    pub fn new() -> RoundRobin {
        RoundRobin { counters: Vec::new() }
    }
}

impl Default for RoundRobin {
    fn default() -> Self {
        Self::new()
    }
}

impl Router for RoundRobin {
    fn kind(&self) -> RouterKind {
        RouterKind::RoundRobin
    }

    fn route(&mut self, model: ModelId, candidates: &[GroupView]) -> usize {
        if self.counters.len() <= model {
            self.counters.resize(model + 1, 0);
        }
        let turn = self.counters[model];
        self.counters[model] = turn.wrapping_add(1);
        candidates[(turn % candidates.len() as u64) as usize].group
    }
}

/// `least-loaded` — send the arrival to the group with the smallest
/// pending-work queue cost, ties broken by group id. Never picks a group
/// whose queue cost is strictly above another candidate's.
pub struct LeastLoaded;

impl Router for LeastLoaded {
    fn kind(&self) -> RouterKind {
        RouterKind::LeastLoaded
    }

    fn route(&mut self, _model: ModelId, candidates: &[GroupView]) -> usize {
        candidates
            .iter()
            .min_by(|a, b| a.queue_cost.total_cmp(&b.queue_cost).then(a.group.cmp(&b.group)))
            .expect("non-empty candidates")
            .group
    }
}

/// `resident-affinity` — route to a group already holding (or loading)
/// the model, so the request re-hits warm state instead of paying a
/// swap-in; among warm groups the least-loaded wins. When every replica
/// is cold a swap is unavoidable, and the cheapest one wins: lowest
/// swap-in cost, then lowest queue cost, then group id. Consequence
/// (pinned by `rust/tests/router_prop.rs`): a resident replica existing
/// anywhere means this router never triggers a new swap.
pub struct ResidentAffinity;

impl ResidentAffinity {
    /// Sort key: warm groups (rank 0) compare on queue cost; cold groups
    /// (rank 1) compare on swap cost then queue cost.
    fn key(v: &GroupView) -> (u8, f64, f64, usize) {
        if v.warm() {
            (0, v.queue_cost, 0.0, v.group)
        } else {
            (1, v.swap_cost, v.queue_cost, v.group)
        }
    }
}

impl Router for ResidentAffinity {
    fn kind(&self) -> RouterKind {
        RouterKind::ResidentAffinity
    }

    fn route(&mut self, _model: ModelId, candidates: &[GroupView]) -> usize {
        candidates
            .iter()
            .min_by(|a, b| {
                let (ra, pa, sa, ga) = Self::key(a);
                let (rb, pb, sb, gb) = Self::key(b);
                ra.cmp(&rb)
                    .then(pa.total_cmp(&pb))
                    .then(sa.total_cmp(&sb))
                    .then(ga.cmp(&gb))
            })
            .expect("non-empty candidates")
            .group
    }
}

/// Health-aware wrapper over any registered router (DESIGN.md §11): the
/// fault layer marks groups dead (failed), draining (preemption warning
/// or autoscaler leave), or standby (not yet joined), and this wrapper
/// filters them out of the candidate set before the wrapped discipline
/// decides. When every candidate is available it delegates the original
/// slice untouched — decisions *and* the inner router's state evolution
/// are bit-for-bit those of the unwrapped router, which is what keeps
/// the no-fault plan equivalent to the pre-fault simulator.
pub struct HealthAwareRouter {
    inner: Box<dyn Router>,
    /// Scratch for the filtered candidate list (no per-decision alloc).
    scratch: Vec<GroupView>,
}

impl HealthAwareRouter {
    pub fn new(inner: Box<dyn Router>) -> HealthAwareRouter {
        HealthAwareRouter { inner, scratch: Vec::new() }
    }

    /// The wrapped discipline's registry name.
    pub fn inner_name(&self) -> &'static str {
        self.inner.name()
    }

    pub fn inner_kind(&self) -> RouterKind {
        self.inner.kind()
    }

    /// Route one arrival of `model` among the candidates whose group
    /// `available` accepts. Returns `None` when no replica is available
    /// (every host dead/draining) — the caller decides between retry
    /// and a fault drop.
    pub fn route_available(
        &mut self,
        model: ModelId,
        candidates: &[GroupView],
        available: impl Fn(usize) -> bool,
    ) -> Option<usize> {
        if candidates.iter().all(|v| available(v.group)) {
            return Some(self.inner.route(model, candidates));
        }
        self.scratch.clear();
        self.scratch.extend(candidates.iter().filter(|v| available(v.group)).copied());
        if self.scratch.is_empty() {
            None
        } else {
            Some(self.inner.route(model, &self.scratch))
        }
    }
}

// ---------------------------------------------------------------------
// Registry
// ---------------------------------------------------------------------

/// Every routing discipline, in presentation order. `names()`/`describe()`
/// are pinned to this list by `registry_resolves_every_name`, and
/// `make()`'s exhaustive match forces a new `RouterKind` variant through
/// this file.
pub const KINDS: [RouterKind; 3] =
    [RouterKind::RoundRobin, RouterKind::LeastLoaded, RouterKind::ResidentAffinity];

/// All registered router names, in presentation order.
pub fn names() -> &'static [&'static str] {
    &["round-robin", "least-loaded", "resident-affinity"]
}

/// True if `name` is a registered router.
pub fn is_known(name: &str) -> bool {
    names().contains(&name)
}

/// One-line description for CLI listings.
pub fn describe(name: &str) -> Option<&'static str> {
    match name {
        "round-robin" => Some("rotate each model over its replica groups (load-blind, fair)"),
        "least-loaded" => Some("lowest pending-work queue cost wins, ties by group id"),
        "resident-affinity" => {
            Some("prefer groups already holding the model; cheapest swap among cold groups")
        }
        _ => None,
    }
}

/// Look up a router by registry name.
pub fn by_name(name: &str) -> Option<Box<dyn Router>> {
    RouterKind::parse(name).map(make)
}

/// Instantiate the router for a config selector.
pub fn make(kind: RouterKind) -> Box<dyn Router> {
    match kind {
        RouterKind::RoundRobin => Box::new(RoundRobin::new()),
        RouterKind::LeastLoaded => Box::new(LeastLoaded),
        RouterKind::ResidentAffinity => Box::new(ResidentAffinity),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn view(group: usize, queue_cost: f64, residency: Residency, swap_cost: f64) -> GroupView {
        GroupView { group, queue_cost, residency, swap_cost }
    }

    #[test]
    fn registry_resolves_every_name() {
        let from_kinds: Vec<&str> = KINDS.iter().map(|k| k.name()).collect();
        assert_eq!(names(), &from_kinds[..]);
        for &name in names() {
            assert!(is_known(name));
            assert!(describe(name).is_some(), "{name} has no description");
            let r = by_name(name).unwrap_or_else(|| panic!("{name} missing"));
            assert_eq!(r.name(), name);
        }
        assert!(by_name("nope").is_none());
        assert!(!is_known("nope"));
    }

    #[test]
    fn round_robin_rotates_per_model() {
        let mut r = RoundRobin::new();
        let views = vec![
            view(0, 9.0, Residency::Offloaded, 1.0),
            view(2, 0.0, Residency::Resident, 1.0),
            view(5, 3.0, Residency::Offloaded, 1.0),
        ];
        // Model 0 rotates 0 -> 2 -> 5 -> 0 regardless of load/residency.
        assert_eq!(r.route(0, &views), 0);
        assert_eq!(r.route(0, &views), 2);
        assert_eq!(r.route(0, &views), 5);
        assert_eq!(r.route(0, &views), 0);
        // Model 7's rotation is independent of model 0's.
        assert_eq!(r.route(7, &views), 0);
        assert_eq!(r.route(0, &views), 2);
    }

    #[test]
    fn least_loaded_picks_minimum_with_id_tiebreak() {
        let mut r = LeastLoaded;
        let views = vec![
            view(0, 3.0, Residency::Resident, 0.0),
            view(1, 1.0, Residency::Offloaded, 9.0),
            view(2, 1.0, Residency::Offloaded, 0.1),
        ];
        assert_eq!(r.route(0, &views), 1, "min cost wins, lower id breaks the tie");
    }

    #[test]
    fn resident_affinity_prefers_warm_groups() {
        let mut r = ResidentAffinity;
        // A busy resident group still beats an idle cold one.
        let views = vec![
            view(0, 9.0, Residency::Resident, 1.0),
            view(1, 0.0, Residency::Offloaded, 0.1),
        ];
        assert_eq!(r.route(0, &views), 0);
        // Partially resident and loading count as warm.
        let views = vec![
            view(0, 1.0, Residency::Offloaded, 0.1),
            view(1, 5.0, Residency::PartiallyResident { loaded: 1, total: 4 }, 1.0),
            view(2, 6.0, Residency::Loading, 1.0),
        ];
        assert_eq!(r.route(0, &views), 1, "least-loaded warm group wins");
        // All cold: cheapest swap wins, not the emptiest queue.
        let views = vec![
            view(0, 0.0, Residency::Offloaded, 2.0),
            view(1, 4.0, Residency::Offloading, 0.5),
        ];
        assert_eq!(r.route(0, &views), 1);
    }

    #[test]
    fn health_aware_filters_unavailable_groups() {
        let views = vec![
            view(0, 0.0, Residency::Resident, 0.0),
            view(1, 5.0, Residency::Offloaded, 1.0),
            view(2, 9.0, Residency::Offloaded, 1.0),
        ];
        let mut r = HealthAwareRouter::new(by_name("least-loaded").unwrap());
        assert_eq!(r.inner_name(), "least-loaded");
        // All healthy: identical to the unwrapped discipline.
        assert_eq!(r.route_available(0, &views, |_| true), Some(0));
        // Group 0 dead: the best *available* group wins.
        assert_eq!(r.route_available(0, &views, |g| g != 0), Some(1));
        // Everything dead: no destination.
        assert_eq!(r.route_available(0, &views, |_| false), None);
    }

    #[test]
    fn health_aware_all_available_matches_unwrapped_state_evolution() {
        // Round-robin keeps per-model counters; with every group healthy
        // the wrapper must advance them exactly like the bare router.
        let views = vec![
            view(0, 0.0, Residency::Offloaded, 1.0),
            view(1, 0.0, Residency::Offloaded, 1.0),
            view(2, 0.0, Residency::Offloaded, 1.0),
        ];
        let mut bare = RoundRobin::new();
        let mut wrapped = HealthAwareRouter::new(Box::new(RoundRobin::new()));
        for _ in 0..7 {
            let expect = bare.route(0, &views);
            assert_eq!(wrapped.route_available(0, &views, |_| true), Some(expect));
        }
    }

    #[test]
    fn single_candidate_is_identity_for_every_router() {
        let views = vec![view(3, 7.0, Residency::Offloading, 2.0)];
        for &name in names() {
            let mut r = by_name(name).unwrap();
            for m in 0..4 {
                assert_eq!(r.route(m, &views), 3, "{name}");
            }
        }
    }
}
