//! The paper's system contribution: the centralized engine, per-model
//! request queues, dynamic batching, swap manager with pluggable
//! replacement policies, and the batch/load entry types that flow through
//! the worker pipelines.

pub mod engine;
pub mod entry;
pub mod policy;
pub mod prefetch;
pub mod queues;
pub mod swap;

pub use engine::{Engine, RequestRecord, SwapRecord};
pub use entry::{BatchEntry, Entry, EntryId, LoadDirection, LoadEntry, ModelId, Request, RequestId};
pub use queues::RequestQueues;
pub use swap::{Residency, SwapManager, SwapPlan, SwapStats};
