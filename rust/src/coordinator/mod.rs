//! The paper's system contribution: the centralized engine, per-model
//! request queues, dynamic batching, swap manager with pluggable
//! replacement policies, the scheduling/admission-control registry
//! (DESIGN.md §5), and the batch/load entry types that flow through the
//! worker pipelines.

pub mod autoscale;
pub mod engine;
pub mod entry;
pub mod planner;
pub mod policy;
pub mod prefetch;
pub mod queues;
pub mod router;
pub mod scheduler;
pub mod swap;

pub use autoscale::{GroupLoad, ScaleAction};
pub use engine::{DropRecord, DropReason, Engine, RequestRecord, SwapRecord};
pub use planner::{enumerate_candidates, plan, PlanOutcome};
pub use router::{GroupView, Router};
pub use scheduler::{Candidate, ModelCost, SchedCtx, Scheduler};
pub use entry::{BatchEntry, Entry, EntryId, LoadDirection, LoadEntry, ModelId, Request, RequestId};
pub use queues::RequestQueues;
pub use swap::{Residency, SwapManager, SwapPlan, SwapStats};
