//! Per-model timestamped request queues (§3.1).
//!
//! The engine "pushes the request object along with a timestamp into a
//! queue specifically for that model", then repeatedly picks the queue
//! whose head is oldest and packs a batch from it.

use std::collections::VecDeque;

use crate::coordinator::entry::{ModelId, Request};

/// All per-model FIFO queues.
#[derive(Debug)]
pub struct RequestQueues {
    queues: Vec<VecDeque<Request>>,
}

impl RequestQueues {
    pub fn new(num_models: usize) -> RequestQueues {
        RequestQueues { queues: (0..num_models).map(|_| VecDeque::new()).collect() }
    }

    pub fn num_models(&self) -> usize {
        self.queues.len()
    }

    /// Enqueue a request into its model's queue.
    pub fn push(&mut self, req: Request) {
        let q = &mut self.queues[req.model];
        debug_assert!(
            q.back().map_or(true, |r| r.arrival <= req.arrival),
            "arrivals must be pushed in time order per model"
        );
        q.push_back(req);
    }

    /// Arrival time of the oldest request for `model`, if any.
    pub fn head_arrival(&self, model: ModelId) -> Option<f64> {
        self.queues[model].front().map(|r| r.arrival)
    }

    /// The oldest queued request for `model`, if any.
    pub fn head(&self, model: ModelId) -> Option<&Request> {
        self.queues[model].front()
    }

    /// Remove and return the oldest queued request for `model` (used by
    /// shedding admission control to drop an infeasible head).
    pub fn pop_head(&mut self, model: ModelId) -> Option<Request> {
        self.queues[model].pop_front()
    }

    /// Model whose queue head is oldest (the paper's scheduling key),
    /// restricted to `eligible`. Ties break by lowest model id.
    pub fn oldest_head(&self, eligible: impl Fn(ModelId) -> bool) -> Option<ModelId> {
        let mut best: Option<(f64, ModelId)> = None;
        for (m, q) in self.queues.iter().enumerate() {
            if !eligible(m) {
                continue;
            }
            if let Some(front) = q.front() {
                match best {
                    Some((t, _)) if t <= front.arrival => {}
                    _ => best = Some((front.arrival, m)),
                }
            }
        }
        best.map(|(_, m)| m)
    }

    /// Pop up to `max` oldest requests from `model`'s queue.
    pub fn pop_batch(&mut self, model: ModelId, max: usize) -> Vec<Request> {
        let q = &mut self.queues[model];
        let n = q.len().min(max);
        q.drain(..n).collect()
    }

    pub fn len(&self, model: ModelId) -> usize {
        self.queues[model].len()
    }

    pub fn total_len(&self) -> usize {
        self.queues.iter().map(VecDeque::len).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.queues.iter().all(VecDeque::is_empty)
    }

    /// Models with at least one queued request.
    pub fn nonempty_models(&self) -> Vec<ModelId> {
        self.nonempty_iter().collect()
    }

    /// Iterator form of [`RequestQueues::nonempty_models`] — the engine's
    /// pump loop calls this once per scheduling round, so it must not
    /// allocate.
    pub fn nonempty_iter(&self) -> impl Iterator<Item = ModelId> + '_ {
        (0..self.queues.len()).filter(move |&m| !self.queues[m].is_empty())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, model: ModelId, arrival: f64) -> Request {
        Request { id, model, arrival, input_len: 8 }
    }

    #[test]
    fn push_pop_fifo_per_model() {
        let mut q = RequestQueues::new(2);
        q.push(req(1, 0, 1.0));
        q.push(req(2, 0, 2.0));
        q.push(req(3, 1, 1.5));
        let batch = q.pop_batch(0, 10);
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 2]);
        assert_eq!(q.len(0), 0);
        assert_eq!(q.len(1), 1);
    }

    #[test]
    fn pop_batch_respects_max() {
        let mut q = RequestQueues::new(1);
        for i in 0..10 {
            q.push(req(i, 0, i as f64));
        }
        let batch = q.pop_batch(0, 4);
        assert_eq!(batch.len(), 4);
        assert_eq!(batch[0].id, 0);
        assert_eq!(q.len(0), 6);
    }

    #[test]
    fn oldest_head_picks_globally_oldest() {
        let mut q = RequestQueues::new(3);
        q.push(req(1, 0, 5.0));
        q.push(req(2, 1, 3.0));
        q.push(req(3, 2, 4.0));
        assert_eq!(q.oldest_head(|_| true), Some(1));
        // With model 1 ineligible (e.g. loading), next oldest wins.
        assert_eq!(q.oldest_head(|m| m != 1), Some(2));
    }

    #[test]
    fn oldest_head_tie_breaks_by_id() {
        let mut q = RequestQueues::new(2);
        q.push(req(1, 1, 2.0));
        q.push(req(2, 0, 2.0));
        assert_eq!(q.oldest_head(|_| true), Some(0));
    }

    #[test]
    fn oldest_head_empty_none() {
        let q = RequestQueues::new(2);
        assert_eq!(q.oldest_head(|_| true), None);
    }

    #[test]
    fn head_and_pop_head() {
        let mut q = RequestQueues::new(2);
        q.push(req(1, 0, 1.0));
        q.push(req(2, 0, 2.0));
        assert_eq!(q.head(0).map(|r| r.id), Some(1));
        assert_eq!(q.head(1).map(|r| r.id), None);
        assert_eq!(q.pop_head(0).map(|r| r.id), Some(1));
        assert_eq!(q.head(0).map(|r| r.id), Some(2));
        assert_eq!(q.pop_head(1).map(|r| r.id), None);
    }

    #[test]
    fn counters() {
        let mut q = RequestQueues::new(3);
        assert!(q.is_empty());
        q.push(req(1, 0, 1.0));
        q.push(req(2, 2, 1.0));
        assert_eq!(q.total_len(), 2);
        assert_eq!(q.nonempty_models(), vec![0, 2]);
        assert!(!q.is_empty());
    }
}
