//! Requests and the two engine→worker action types from §3 of the paper:
//! **batch entries** (evaluate a model on a packed batch of requests) and
//! **load entries** (load or offload one model's parameter shards).

/// Index of a registered model instance.
pub type ModelId = usize;
/// Unique id of one client request.
pub type RequestId = u64;
/// Unique id of one engine-submitted entry (batch or load).
pub type EntryId = u64;

/// One inference request.
#[derive(Clone, Debug, PartialEq)]
pub struct Request {
    pub id: RequestId,
    pub model: ModelId,
    /// Arrival timestamp at the engine (sim seconds or unix seconds).
    pub arrival: f64,
    /// Input token length.
    pub input_len: usize,
}

/// Direction of a load entry.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum LoadDirection {
    /// CPU → GPU: make the model resident.
    Load,
    /// GPU → CPU: evict the model (parameters stay pinned on the host).
    Offload,
    /// Abort an in-flight chunked load: stop dispatching further chunks
    /// and discard the chunks already on the GPU (the host copy stays
    /// pinned, so nothing needs to drain back). Only the chunked swap
    /// pipeline emits these (DESIGN.md §6); workers ack once the
    /// in-flight chunk, if any, completes.
    Cancel,
}

impl LoadDirection {
    pub fn name(self) -> &'static str {
        match self {
            LoadDirection::Load => "load",
            LoadDirection::Offload => "offload",
            LoadDirection::Cancel => "cancel",
        }
    }
}

/// A packed batch of requests for one model, pipelined through all stages.
///
/// The request list is shared (`Arc`): a batch entry is cloned once per
/// TP lane at routing time and once into the engine's in-flight table, so
/// a deep `Vec` clone on every submit was measurable on the sim hot path.
#[derive(Clone, Debug)]
pub struct BatchEntry {
    pub id: EntryId,
    pub model: ModelId,
    pub requests: std::sync::Arc<[Request]>,
    /// Max input length in the batch (padding length for execution).
    pub seqlen: usize,
}

impl BatchEntry {
    pub fn new(id: EntryId, model: ModelId, requests: Vec<Request>) -> BatchEntry {
        assert!(!requests.is_empty(), "empty batch entry");
        debug_assert!(requests.iter().all(|r| r.model == model));
        let seqlen = requests.iter().map(|r| r.input_len).max().unwrap();
        BatchEntry { id, model, requests: requests.into(), seqlen }
    }

    pub fn batch_size(&self) -> usize {
        self.requests.len()
    }
}

/// A command to move one model's shards between CPU and GPU memory.
#[derive(Clone, Debug, PartialEq)]
pub struct LoadEntry {
    pub id: EntryId,
    pub model: ModelId,
    pub dir: LoadDirection,
}

/// Anything the engine submits into the worker pipeline.
#[derive(Clone, Debug)]
pub enum Entry {
    Batch(BatchEntry),
    Load(LoadEntry),
}

impl Entry {
    pub fn id(&self) -> EntryId {
        match self {
            Entry::Batch(b) => b.id,
            Entry::Load(l) => l.id,
        }
    }

    pub fn model(&self) -> ModelId {
        match self {
            Entry::Batch(b) => b.model,
            Entry::Load(l) => l.model,
        }
    }

    pub fn is_load(&self) -> bool {
        matches!(self, Entry::Load(_))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: RequestId, model: ModelId, len: usize) -> Request {
        Request { id, model, arrival: 0.0, input_len: len }
    }

    #[test]
    fn batch_entry_packs_and_pads() {
        let b = BatchEntry::new(1, 0, vec![req(1, 0, 2), req(2, 0, 8), req(3, 0, 4)]);
        assert_eq!(b.batch_size(), 3);
        assert_eq!(b.seqlen, 8);
    }

    #[test]
    #[should_panic(expected = "empty batch entry")]
    fn empty_batch_rejected() {
        BatchEntry::new(1, 0, vec![]);
    }

    #[test]
    fn entry_accessors() {
        let b = Entry::Batch(BatchEntry::new(7, 3, vec![req(1, 3, 2)]));
        let l = Entry::Load(LoadEntry { id: 8, model: 4, dir: LoadDirection::Load });
        assert_eq!(b.id(), 7);
        assert_eq!(b.model(), 3);
        assert!(!b.is_load());
        assert_eq!(l.id(), 8);
        assert_eq!(l.model(), 4);
        assert!(l.is_load());
        assert_eq!(LoadDirection::Load.name(), "load");
        assert_eq!(LoadDirection::Offload.name(), "offload");
    }
}
