//! Experiment reporting: turn raw request/swap records into the exact
//! artifacts the paper publishes — average-latency tables (Tab 1, Tab 2),
//! latency CDFs (Fig 8, Fig 9), and swap-scaling series (Fig 5–7) — plus
//! JSON export for downstream plotting.

use crate::coordinator::engine::{RequestRecord, SwapRecord};
use crate::sim::system::SimReport;
use crate::util::json::Json;
use crate::util::stats::{cdf_sorted, Summary};

/// Measured outcome of one (skew, CV) cell of Tab 1 / Tab 2, extended
/// with the SLO-serving metrics (deadline attainment, goodput, drop
/// rate) that `benches/slo_suite.rs` sweeps.
#[derive(Clone, Debug)]
pub struct WorkloadCell {
    pub skew_label: String,
    pub cv: f64,
    /// Average end-to-end latency over the measured window (the table
    /// entry the paper reports).
    pub mean_latency: f64,
    pub summary: Summary,
    /// (latency, F(latency)) CDF points — Fig 8 / Fig 9 series.
    pub cdf: Vec<(f64, f64)>,
    pub requests: usize,
    pub swaps: usize,
    /// Swaps cancelled mid-transfer in the measured window (chunked
    /// pipeline only).
    pub cancelled_swaps: usize,
    /// Mean time-to-first-chunk over completed measured swaps: how long a
    /// cold model waits before its first layers can compute. Equals the
    /// mean load latency for monolithic transfers; 0 when no swaps.
    pub mean_ttfc: f64,
    /// Mean fraction of load chunks that landed while a batch for the
    /// loading model was in flight (transfer hidden behind compute).
    pub mean_overlap: f64,
    /// Requests dropped by admission control in the measured window.
    pub drops: usize,
    /// Fraction of measured *completed* requests that met their deadline
    /// (1.0 when no SLOs are configured — every deadline is infinite).
    pub attainment: f64,
    /// Deadline-met completions per second of measured window (the
    /// SLO-serving literature's goodput); 0 when the window length is
    /// unknown (`duration <= 0`).
    pub goodput: f64,
    /// drops / (completions + drops) over the measured window.
    pub drop_rate: f64,
}

impl WorkloadCell {
    /// Build a cell from a simulation report, filtering out warmup.
    /// `duration` is the measured-window length in seconds (the goodput
    /// denominator); pass 0.0 when it is unknown.
    pub fn from_report(
        skew_label: &str,
        cv: f64,
        report: &SimReport,
        measure_start: f64,
        duration: f64,
    ) -> WorkloadCell {
        let measured: Vec<&RequestRecord> =
            report.requests.iter().filter(|r| r.arrival >= measure_start).collect();
        // Sort the latency sample once; the summary, every percentile,
        // and the CDF all derive from the same sorted slice.
        let mut lats: Vec<f64> = measured.iter().map(|r| r.latency()).collect();
        lats.sort_by(|a, b| a.partial_cmp(b).expect("NaN in latency sample"));
        let summary = Summary::of_sorted(&lats).unwrap_or_else(Summary::empty);
        let attained = measured.iter().filter(|r| r.attained()).count();
        let drops = report.drops.iter().filter(|d| d.arrival >= measure_start).count();
        let served = measured.len();
        let measured_swaps: Vec<&SwapRecord> =
            report.swaps.iter().filter(|s| s.submitted >= measure_start).collect();
        let completed_swaps: Vec<&SwapRecord> =
            measured_swaps.iter().copied().filter(|s| !s.cancelled).collect();
        let swap_mean = |f: fn(&SwapRecord) -> f64| {
            if completed_swaps.is_empty() {
                0.0
            } else {
                completed_swaps.iter().map(|&s| f(s)).sum::<f64>() / completed_swaps.len() as f64
            }
        };
        WorkloadCell {
            skew_label: skew_label.to_string(),
            cv,
            mean_latency: summary.mean,
            summary: summary.clone(),
            cdf: cdf_sorted(&lats, 100),
            requests: served,
            swaps: measured_swaps.len(),
            cancelled_swaps: measured_swaps.iter().filter(|s| s.cancelled).count(),
            mean_ttfc: swap_mean(|s| s.time_to_first_chunk),
            mean_overlap: swap_mean(|s| s.overlap_fraction),
            drops,
            attainment: if served == 0 { 0.0 } else { attained as f64 / served as f64 },
            goodput: if duration > 0.0 { attained as f64 / duration } else { 0.0 },
            drop_rate: if served + drops == 0 {
                0.0
            } else {
                drops as f64 / (served + drops) as f64
            },
        }
    }

    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("skew", self.skew_label.as_str().into()),
            ("cv", self.cv.into()),
            ("mean_latency", self.mean_latency.into()),
            ("summary", self.summary.to_json()),
            (
                "cdf",
                Json::Arr(
                    self.cdf
                        .iter()
                        .map(|&(x, f)| Json::Arr(vec![x.into(), f.into()]))
                        .collect(),
                ),
            ),
            ("requests", self.requests.into()),
            ("swaps", self.swaps.into()),
            ("cancelled_swaps", self.cancelled_swaps.into()),
            ("mean_ttfc", self.mean_ttfc.into()),
            ("mean_overlap", self.mean_overlap.into()),
            ("drops", self.drops.into()),
            ("attainment", self.attainment.into()),
            ("goodput", self.goodput.into()),
            ("drop_rate", self.drop_rate.into()),
        ])
    }
}

/// One point of the Fig 5/6/7 swap-scaling series.
#[derive(Clone, Debug)]
pub struct SwapScalingPoint {
    pub tp: usize,
    pub pp: usize,
    pub mean_swap: f64,
    pub mean_exec: f64,
    pub mean_e2e: f64,
    /// Mean time-to-first-chunk: when a cold model's first layers can
    /// start computing (== mean load latency for monolithic transfers).
    pub mean_ttfc: f64,
    /// Mean fraction of the load hidden behind compute (0 monolithic).
    pub mean_overlap: f64,
    /// 24 GB / (n · 32 GB/s): the paper's ideal target.
    pub ideal: f64,
}

impl SwapScalingPoint {
    pub fn from_records(
        tp: usize,
        pp: usize,
        swaps: &[SwapRecord],
        requests: &[RequestRecord],
        model_bytes: usize,
        link_bandwidth: f64,
    ) -> SwapScalingPoint {
        // Cancelled swaps (chunked pipeline) never completed a transfer —
        // their duration is submit → cancel-ack — so every swap statistic
        // here averages completed swaps only.
        let completed: Vec<&SwapRecord> = swaps.iter().filter(|s| !s.cancelled).collect();
        let mean_swap = mean(completed.iter().map(|s| s.duration()));
        let mean_e2e = mean(requests.iter().map(RequestRecord::latency));
        SwapScalingPoint {
            tp,
            pp,
            mean_swap,
            mean_exec: mean_e2e - mean_swap,
            mean_e2e,
            mean_ttfc: mean(completed.iter().map(|s| s.time_to_first_chunk)),
            mean_overlap: mean(completed.iter().map(|s| s.overlap_fraction)),
            ideal: model_bytes as f64 / ((tp * pp) as f64 * link_bandwidth),
        }
    }

    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("tp", self.tp.into()),
            ("pp", self.pp.into()),
            ("mean_swap", self.mean_swap.into()),
            ("mean_exec", self.mean_exec.into()),
            ("mean_e2e", self.mean_e2e.into()),
            ("mean_ttfc", self.mean_ttfc.into()),
            ("mean_overlap", self.mean_overlap.into()),
            ("ideal", self.ideal.into()),
        ])
    }
}

fn mean(iter: impl Iterator<Item = f64>) -> f64 {
    let values: Vec<f64> = iter.collect();
    if values.is_empty() {
        0.0
    } else {
        values.iter().sum::<f64>() / values.len() as f64
    }
}

/// Measured outcome of one engine group over the measured window
/// (cluster runs, DESIGN.md §8). Built from the flat record vectors via
/// their `group` tags plus the run's `GroupStats` aggregates.
#[derive(Clone, Debug)]
pub struct GroupCell {
    pub group: usize,
    /// Catalog ids this group hosts.
    pub models: Vec<usize>,
    /// Completed requests arriving in the measured window.
    pub requests: usize,
    /// Admission-control drops arriving in the measured window.
    pub drops: usize,
    pub mean_latency: f64,
    /// Fraction of this group's measured completions that met their
    /// deadline (1.0 when no SLOs are configured; 0.0 for a group with
    /// no measured completions — `WorkloadCell`'s empty-window
    /// convention).
    pub attainment: f64,
    /// Deadline-met completions per second of measured window.
    pub goodput: f64,
    /// Completed swap-ins over the whole run (not window-filtered — swap
    /// traffic is a capacity metric, not a latency one).
    pub swaps: usize,
    /// Σ swap-in shard bytes over the whole run.
    pub swap_bytes: u64,
}

impl GroupCell {
    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("group", self.group.into()),
            ("models", Json::Arr(self.models.iter().map(|&m| m.into()).collect())),
            ("requests", self.requests.into()),
            ("drops", self.drops.into()),
            ("mean_latency", self.mean_latency.into()),
            ("attainment", self.attainment.into()),
            ("goodput", self.goodput.into()),
            ("swaps", self.swaps.into()),
            ("swap_bytes", (self.swap_bytes as usize).into()),
        ])
    }
}

/// One `GroupCell` per engine group of a run, in group order.
pub fn group_cells(report: &SimReport, measure_start: f64, duration: f64) -> Vec<GroupCell> {
    report
        .groups
        .iter()
        .map(|g| {
            let measured: Vec<&RequestRecord> = report
                .requests
                .iter()
                .filter(|r| r.group == g.group && r.arrival >= measure_start)
                .collect();
            let attained = measured.iter().filter(|r| r.attained()).count();
            let lats: Vec<f64> = measured.iter().map(|r| r.latency()).collect();
            GroupCell {
                group: g.group,
                models: g.models.clone(),
                requests: measured.len(),
                drops: report
                    .drops
                    .iter()
                    .filter(|d| d.group == g.group && d.arrival >= measure_start)
                    .count(),
                mean_latency: mean(lats.into_iter()),
                attainment: if measured.is_empty() {
                    0.0
                } else {
                    attained as f64 / measured.len() as f64
                },
                goodput: if duration > 0.0 { attained as f64 / duration } else { 0.0 },
                swaps: g.swaps,
                swap_bytes: g.swap_bytes,
            }
        })
        .collect()
}

/// Cross-group load imbalance: max / mean of per-group measured arrival
/// counts (completions + drops — routed traffic, not just served).
/// 1.0 is a perfect spread; G is one group taking everything. 0.0 when
/// there is no traffic (or no groups).
pub fn load_imbalance(cells: &[GroupCell]) -> f64 {
    if cells.is_empty() {
        return 0.0;
    }
    let counts: Vec<f64> = cells.iter().map(|c| (c.requests + c.drops) as f64).collect();
    let total: f64 = counts.iter().sum();
    if total == 0.0 {
        return 0.0;
    }
    let mean = total / counts.len() as f64;
    counts.iter().cloned().fold(0.0, f64::max) / mean
}

/// Per-model SLO attainment over the measured window, indexed by catalog
/// model id: deadline-met completions over *all* of the model's measured
/// arrivals — a dropped request counts as a miss, so 100% shed traffic
/// reports 0.0, not 1.0. Models with no measured traffic report 0.0
/// (the empty-window convention `WorkloadCell` uses).
pub fn per_model_attainment(report: &SimReport, measure_start: f64) -> Vec<f64> {
    let n = report
        .requests
        .iter()
        .map(|r| r.model + 1)
        .chain(report.groups.iter().flat_map(|g| g.models.iter().map(|&m| m + 1)))
        .max()
        .unwrap_or(0);
    let mut arrived = vec![0usize; n];
    let mut attained = vec![0usize; n];
    for r in report.requests.iter().filter(|r| r.arrival >= measure_start) {
        arrived[r.model] += 1;
        if r.attained() {
            attained[r.model] += 1;
        }
    }
    for d in report.drops.iter().filter(|d| d.arrival >= measure_start) {
        arrived[d.model] += 1;
    }
    (0..n)
        .map(|m| if arrived[m] == 0 { 0.0 } else { attained[m] as f64 / arrived[m] as f64 })
        .collect()
}

/// Render a Tab-1/Tab-2-style grid: rows = skew, columns = CV.
pub fn latency_table(cells: &[WorkloadCell], cvs: &[f64]) -> (Vec<&'static str>, Vec<Vec<String>>) {
    let mut skews: Vec<String> = Vec::new();
    for c in cells {
        if !skews.contains(&c.skew_label) {
            skews.push(c.skew_label.clone());
        }
    }
    let rows: Vec<Vec<String>> = skews
        .iter()
        .map(|skew| {
            let mut row = vec![skew.clone()];
            for &cv in cvs {
                let cell = cells
                    .iter()
                    .find(|c| &c.skew_label == skew && (c.cv - cv).abs() < 1e-9);
                row.push(match cell {
                    Some(c) => format!("{:.3}", c.mean_latency),
                    None => "-".to_string(),
                });
            }
            row
        })
        .collect();
    (vec!["Skew", "CV = 0.25", "CV = 1", "CV = 4"], rows)
}

/// Write a set of cells to a JSON report file.
pub fn save_cells(path: &std::path::Path, experiment: &str, cells: &[WorkloadCell]) -> anyhow::Result<()> {
    let j = Json::from_pairs(vec![
        ("experiment", experiment.into()),
        ("cells", Json::Arr(cells.iter().map(WorkloadCell::to_json).collect())),
    ]);
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    std::fs::write(path, j.pretty())?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;
    use crate::sim::{Driver, SimSystem};

    fn small_report() -> SimReport {
        let cfg = SystemConfig::swap_experiment(2, 2);
        let mut sys = SimSystem::new(cfg, Driver::AlternatingBlocking {
            models: 2,
            input_len: 2,
            total: 6,
        })
        .unwrap();
        sys.preload(&[1]);
        sys.run()
    }

    #[test]
    fn cell_from_report() {
        let r = small_report();
        let cell = WorkloadCell::from_report("(1,1)", 1.0, &r, 0.0, 10.0);
        assert_eq!(cell.requests, 6);
        assert!(cell.mean_latency > 0.0);
        assert!(!cell.cdf.is_empty());
        let j = cell.to_json();
        assert_eq!(j.get("skew").unwrap().as_str().unwrap(), "(1,1)");
    }

    #[test]
    fn slo_metrics_in_cells() {
        use crate::config::SchedulerKind;
        use crate::sim::Arrival;
        // No SLOs: every completion attains; goodput = completions / window.
        let r = small_report();
        let cell = WorkloadCell::from_report("x", 1.0, &r, 0.0, 10.0);
        assert_eq!(cell.attainment, 1.0);
        assert_eq!(cell.drops, 0);
        assert_eq!(cell.drop_rate, 0.0);
        assert!((cell.goodput - cell.requests as f64 / 10.0).abs() < 1e-12);

        // Overloaded shed run: drops appear in the cell and the rate is
        // consistent with the counts.
        let mut cfg = SystemConfig::workload_experiment(2, 1, 4);
        cfg.engine.scheduler = SchedulerKind::Shed;
        cfg.set_slos(&[1.0, 1.0]).unwrap();
        let arrivals: Vec<Arrival> = (0..100)
            .map(|i| Arrival { at: 0.02 * i as f64, model: i % 2, input_len: 8 })
            .collect();
        let mut sys = SimSystem::new(cfg, Driver::Open(arrivals)).unwrap();
        sys.preload(&[0]);
        let r = sys.run();
        let cell = WorkloadCell::from_report("shed", 1.0, &r, 0.0, 2.0);
        assert_eq!(cell.requests + cell.drops, 100);
        assert!(cell.drops > 0);
        assert!((cell.drop_rate - cell.drops as f64 / 100.0).abs() < 1e-12);
        assert!(cell.attainment <= 1.0);
        let j = cell.to_json();
        assert!(j.get("drop_rate").unwrap().as_f64().unwrap() > 0.0);
        assert!(j.get("attainment").is_some() && j.get("goodput").is_some());
    }

    #[test]
    fn chunk_metrics_in_cells() {
        // Monolithic run: ttfc equals the load latency (first chunk ==
        // whole shard), overlap is zero, nothing cancelled.
        let r = small_report();
        let cell = WorkloadCell::from_report("x", 1.0, &r, 0.0, 10.0);
        assert!(cell.mean_ttfc > 0.0);
        assert_eq!(cell.mean_overlap, 0.0);
        assert_eq!(cell.cancelled_swaps, 0);
        let j = cell.to_json();
        assert!(j.get("mean_ttfc").unwrap().as_f64().unwrap() > 0.0);
        assert_eq!(j.get("mean_overlap").unwrap().as_f64().unwrap(), 0.0);
        assert_eq!(j.get("cancelled_swaps").unwrap().as_usize().unwrap(), 0);

        // Chunked run: first chunk lands well before the full load and
        // some transfer hides behind compute.
        let mut cfg = SystemConfig::swap_experiment(2, 2);
        cfg.engine.load_design = crate::config::LoadDesign::ChunkedPipelined;
        let mut sys = SimSystem::new(cfg, Driver::AlternatingBlocking {
            models: 2,
            input_len: 2,
            total: 6,
        })
        .unwrap();
        sys.preload(&[1]);
        let rc = sys.run();
        let chunked = WorkloadCell::from_report("x", 1.0, &rc, 0.0, 10.0);
        assert!(chunked.mean_ttfc < cell.mean_ttfc);
        assert!(chunked.mean_overlap > 0.0);
    }

    #[test]
    fn scaling_point_math() {
        let r = small_report();
        let p = SwapScalingPoint::from_records(2, 2, &r.swaps, &r.requests, 24_000_000_000, 32.0e9);
        assert!((p.ideal - 0.1875).abs() < 1e-9);
        assert!(p.mean_swap > p.ideal, "measured swap must exceed ideal");
        assert!((p.mean_e2e - p.mean_swap - p.mean_exec).abs() < 1e-9);
    }

    #[test]
    fn table_layout() {
        let r = small_report();
        let cells = vec![
            WorkloadCell::from_report("(1,1,1)", 0.25, &r, 0.0, 0.0),
            WorkloadCell::from_report("(1,1,1)", 1.0, &r, 0.0, 0.0),
            WorkloadCell::from_report("(10,1,1)", 0.25, &r, 0.0, 0.0),
        ];
        let (headers, rows) = latency_table(&cells, &[0.25, 1.0, 4.0]);
        assert_eq!(headers.len(), 4);
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0][0], "(1,1,1)");
        assert_eq!(rows[1][3], "-"); // missing CV=4 cell
    }

    #[test]
    fn group_cells_and_imbalance() {
        use crate::config::{PlacementSpec, RouterKind};
        use crate::sim::Arrival;
        // Single group: one cell covering everything, imbalance 1.0.
        let r = small_report();
        let cells = group_cells(&r, 0.0, 10.0);
        assert_eq!(cells.len(), 1);
        assert_eq!(cells[0].requests, r.requests.len());
        assert_eq!(cells[0].swaps, r.groups[0].swaps);
        assert_eq!(cells[0].swap_bytes, r.groups[0].swap_bytes);
        assert!((load_imbalance(&cells) - 1.0).abs() < 1e-12);
        assert!(cells[0].to_json().get("goodput").is_some());

        // Two replicated groups under round-robin: both serve traffic and
        // the imbalance stays near 1 (perfect alternation = exactly 1).
        let mut cfg = SystemConfig::workload_experiment(2, 1, 8);
        cfg.placement =
            Some(PlacementSpec::replicated(2, cfg.parallel, 2, RouterKind::RoundRobin));
        let arrivals: Vec<Arrival> = (0..16)
            .map(|i| Arrival { at: 0.5 * i as f64, model: i % 2, input_len: 8 })
            .collect();
        let mut sys = SimSystem::new(cfg, Driver::Open(arrivals)).unwrap();
        sys.preload_warm();
        let r = sys.run();
        let cells = group_cells(&r, 0.0, 8.0);
        assert_eq!(cells.len(), 2);
        assert_eq!(cells[0].requests + cells[1].requests, 16);
        assert_eq!(cells[0].requests, 8);
        assert!((load_imbalance(&cells) - 1.0).abs() < 1e-12);
        // Empty cell list and zero traffic degenerate to 0.
        assert_eq!(load_imbalance(&[]), 0.0);
    }

    #[test]
    fn per_model_attainment_splits_by_catalog_id() {
        use crate::config::SchedulerKind;
        use crate::sim::Arrival;
        // §5.1 worst case at TP=1 PP=1 with a 0.5 s SLO: model 1 always
        // swaps in cold (pure transfer alone is 0.75 s — provably a
        // miss), while model 0's first request hits its preloaded copy
        // and attains. Per-model attainment must split accordingly.
        let mut cfg = SystemConfig::swap_experiment(1, 1);
        cfg.engine.scheduler = SchedulerKind::Fcfs;
        cfg.set_slos(&[0.5, 0.5]).unwrap();
        let arrivals: Vec<Arrival> = (0..8)
            .map(|i| Arrival { at: 3.0 * i as f64, model: i % 2, input_len: 2 })
            .collect();
        let mut sys = SimSystem::new(cfg, Driver::Open(arrivals)).unwrap();
        sys.preload(&[0]);
        let r = sys.run();
        let att = per_model_attainment(&r, 0.0);
        assert_eq!(att.len(), 2);
        assert!(att.iter().all(|a| (0.0..=1.0).contains(a)));
        assert_eq!(att[1], 0.0, "cold swaps can never meet a 0.5 s SLO: {att:?}");
        assert!(att[1] < att[0], "the swapping model must attain less: {att:?}");
    }

    #[test]
    fn save_cells_writes_json() {
        let r = small_report();
        let cells = vec![WorkloadCell::from_report("(1,1)", 4.0, &r, 0.0, 0.0)];
        let dir = std::env::temp_dir().join("computron_metrics_test");
        let path = dir.join("cells.json");
        save_cells(&path, "tab1", &cells).unwrap();
        let j = Json::parse_file(&path).unwrap();
        assert_eq!(j.get("experiment").unwrap().as_str().unwrap(), "tab1");
        std::fs::remove_file(&path).ok();
    }
}
