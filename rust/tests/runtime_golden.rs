//! Cross-language integration tests: the rust PJRT pipeline (sharded
//! weights + stage executables + in-process collectives) must reproduce
//! the python reference forward's golden logits from the artifact
//! manifest. This is the anchor proving L3 (rust) faithfully executes
//! L2/L1 (jax + pallas) artifacts.
//!
//! Requires `make artifacts` (skips gracefully when absent so plain
//! `cargo test` works before the python build).

use computron::runtime::{forward_pipeline, Manifest, WorkerRuntime};

fn manifest() -> Option<Manifest> {
    let dir = computron::runtime::manifest::default_dir();
    if dir.join("manifest.json").exists() {
        Some(Manifest::load(&dir).expect("manifest should parse"))
    } else {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        None
    }
}

fn build_grid(m: &Manifest, model: &str, tp: usize, pp: usize, instances: usize) -> Vec<Vec<WorkerRuntime>> {
    (0..pp)
        .map(|pp_rank| {
            (0..tp)
                .map(|tp_rank| {
                    WorkerRuntime::new(m, model, tp, pp, tp_rank, pp_rank, instances)
                        .expect("runtime builds")
                })
                .collect()
        })
        .collect()
}

fn check_golden(m: &Manifest, model: &str, tp: usize, pp: usize) {
    let golden = &m.golden[model];
    let spec = &m.models[model];
    let (b, s) = (golden.batch, golden.seq);
    let mut grid = build_grid(m, model, tp, pp, 1);
    for row in &mut grid {
        for rt in row {
            rt.load(0).expect("load instance 0");
        }
    }
    let logits = forward_pipeline(&grid, 0, &golden.ids, (b, s)).expect("pipeline runs");
    // Compare last-position logits per batch row.
    let vocab = spec.vocab;
    let mut max_err = 0.0f32;
    for row in 0..b {
        let pos = row * s + (s - 1);
        for v in 0..vocab {
            let got = logits[pos * vocab + v];
            let want = golden.last_logits[row * vocab + v];
            max_err = max_err.max((got - want).abs());
        }
        // Argmax must agree exactly.
        let got_argmax = (0..vocab)
            .max_by(|&a, &bb| {
                logits[pos * vocab + a].total_cmp(&logits[pos * vocab + bb])
            })
            .unwrap();
        assert_eq!(got_argmax, golden.argmax[row], "argmax mismatch tp={tp} pp={pp} row={row}");
    }
    assert!(
        (max_err as f64) < golden.tolerance,
        "tp={tp} pp={pp}: max err {max_err} over tolerance {}",
        golden.tolerance
    );
}

#[test]
fn golden_tp1_pp1() {
    let Some(m) = manifest() else { return };
    check_golden(&m, "opt-test", 1, 1);
}

#[test]
fn golden_tp2_pp1() {
    let Some(m) = manifest() else { return };
    check_golden(&m, "opt-test", 2, 1);
}

#[test]
fn golden_tp1_pp2() {
    let Some(m) = manifest() else { return };
    check_golden(&m, "opt-test", 1, 2);
}

#[test]
fn golden_tp2_pp2() {
    let Some(m) = manifest() else { return };
    check_golden(&m, "opt-test", 2, 2);
}

#[test]
fn load_offload_cycle_preserves_results() {
    let Some(m) = manifest() else { return };
    let golden = &m.golden["opt-test"];
    let mut grid = build_grid(&m, "opt-test", 1, 1, 1);
    grid[0][0].load(0).unwrap();
    let first = forward_pipeline(&grid, 0, &golden.ids, (golden.batch, golden.seq)).unwrap();
    // Offload and reload: results must be identical (host copy is
    // authoritative — the §3.2 pinned-memory design).
    grid[0][0].offload(0).unwrap();
    assert!(!grid[0][0].is_loaded(0));
    grid[0][0].load(0).unwrap();
    let second = forward_pipeline(&grid, 0, &golden.ids, (golden.batch, golden.seq)).unwrap();
    assert_eq!(first, second);
}

#[test]
fn distinct_instances_have_distinct_weights() {
    let Some(m) = manifest() else { return };
    let golden = &m.golden["opt-test"];
    let mut grid = build_grid(&m, "opt-test", 1, 1, 2);
    grid[0][0].load(0).unwrap();
    grid[0][0].load(1).unwrap();
    let a = forward_pipeline(&grid, 0, &golden.ids, (golden.batch, golden.seq)).unwrap();
    let b = forward_pipeline(&grid, 1, &golden.ids, (golden.batch, golden.seq)).unwrap();
    assert_ne!(a, b, "instances must be independently-seeded models");
}

#[test]
fn executing_unloaded_instance_fails() {
    let Some(m) = manifest() else { return };
    let grid = build_grid(&m, "opt-test", 1, 1, 1);
    let golden = &m.golden["opt-test"];
    let err = forward_pipeline(&grid, 0, &golden.ids, (golden.batch, golden.seq));
    assert!(err.is_err(), "load dependency must be enforced");
}

#[test]
fn padded_batch_matches_exact_batch() {
    // Requests padded into a larger bucket must produce the same logits
    // at real positions (causal masking property the batcher relies on).
    let Some(m) = manifest() else { return };
    let golden = &m.golden["opt-test"];
    let spec = &m.models["opt-test"];
    let mut grid = build_grid(&m, "opt-test", 1, 1, 1);
    grid[0][0].load(0).unwrap();
    let (b, s) = (golden.batch, golden.seq);
    let exact = forward_pipeline(&grid, 0, &golden.ids, (b, s)).unwrap();
    // Pad to the batch-8 bucket if present.
    if let Some(bucket) = grid[0][0].pick_bucket(8, s) {
        let mut padded_ids = golden.ids.clone();
        padded_ids.resize(bucket.0 * bucket.1, 0);
        let padded = forward_pipeline(&grid, 0, &padded_ids, bucket).unwrap();
        let vocab = spec.vocab;
        for row in 0..b {
            for pos in 0..s {
                let e = (row * s + pos) * vocab;
                let p = (row * bucket.1 + pos) * vocab;
                for v in 0..vocab {
                    let d = (exact[e + v] - padded[p + v]).abs();
                    assert!(d < 1e-3, "row={row} pos={pos} v={v} d={d}");
                }
            }
        }
    }
}
