//! Preset-drift guard: every JSON file shipped under `configs/` must
//! parse AND validate (this is the test that catches the
//! `slos.len() != num_models` class of preset bugs before a user does),
//! plus round-trip pins for the legacy `num_models` compat shim and the
//! resolved shape of the heterogeneous preset.

use computron::config::{LoadDesign, ModelCatalog, SchedulerKind, SystemConfig};
use computron::util::json::Json;

fn configs_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("configs")
}

#[test]
fn every_shipped_preset_parses_and_validates() {
    let mut seen = Vec::new();
    for entry in std::fs::read_dir(configs_dir()).expect("configs/ exists") {
        let path = entry.expect("dir entry").path();
        if path.extension().and_then(|e| e.to_str()) != Some("json") {
            continue;
        }
        let name = path.file_name().unwrap().to_string_lossy().to_string();
        let cfg = SystemConfig::from_file(&path).unwrap_or_else(|e| panic!("{name}: {e}"));
        cfg.validate().unwrap_or_else(|e| panic!("{name}: {e}"));
        // Re-validate the *resolved* placement (explicit or the legacy
        // single-group shim) against the PlacementSpec feasibility
        // checks: structure, per-group shard divisibility, and the
        // per-group memory bound — exactly what the placement planner
        // enforces on its own candidates (DESIGN.md §10).
        let placement = cfg.resolved_placement();
        placement
            .validate(cfg.num_models())
            .unwrap_or_else(|e| panic!("{name}: resolved placement invalid: {e}"));
        let mut pinned = cfg.clone();
        pinned.placement = Some(placement);
        pinned
            .validate()
            .unwrap_or_else(|e| panic!("{name}: resolved placement infeasible: {e}"));
        // Every preset must also survive a JSON round-trip through the
        // catalog schema with its catalog intact.
        let back = SystemConfig::from_json(&cfg.to_json())
            .unwrap_or_else(|e| panic!("{name} round-trip: {e}"));
        assert_eq!(back.models, cfg.models, "{name}: catalog changed in round-trip");
        assert_eq!(back.parallel, cfg.parallel, "{name}");
        assert_eq!(back.scenario, cfg.scenario, "{name}");
        assert_eq!(back.placement, cfg.placement, "{name}: placement changed in round-trip");
        assert_eq!(back.faults, cfg.faults, "{name}: fault plan changed in round-trip");
        seen.push(name);
    }
    // The known preset set must be present (a rename or deletion here is
    // a doc-breaking change — update README/EXPERIMENTS when it fires).
    for required in [
        "swap_tp2_pp2.json",
        "workload_3model.json",
        "workload_6model.json",
        "slo_3model.json",
        "chunked_3model.json",
        "hetero_4model.json",
        "groups_2x2.json",
        "planned_hetero.json",
        "chaos_spot.json",
        "fleet_variants.json",
    ] {
        assert!(seen.iter().any(|n| n == required), "missing preset {required} (have {seen:?})");
    }
}

#[test]
fn fleet_variants_preset_resolves_expected_tiering() {
    let cfg = SystemConfig::from_file(&configs_dir().join("fleet_variants.json")).unwrap();
    assert_eq!(cfg.num_models(), 6);
    // Resolved base lineage: three opt-6.7b fine-tunes over entry 0, one
    // opt-2.7b fine-tune over entry 4 (first *other* entry by name).
    let bases = cfg.resolved_bases().unwrap();
    assert_eq!(bases, vec![None, Some(0), Some(0), Some(0), None, Some(4)]);
    let fracs: Vec<f64> = cfg.models.iter().map(|d| d.delta_fraction).collect();
    assert_eq!(fracs, vec![1.0, 0.1, 0.15, 0.2, 1.0, 0.25]);
    // Host-tier pin: the preset ships a finite per-group pinned budget
    // over the weighted-cost policy, warm-started.
    let host = cfg.host.as_ref().expect("preset configures a host tier");
    assert_eq!(host.budget, 24_000_000_000);
    assert_eq!(host.policy.name(), "weighted-cost");
    assert!(host.warm_start);
    assert!(!host.shared);
    // The budget is deliberately smaller than the catalog's full host
    // footprint (evictions must be reachable) but big enough for every
    // base plus at least one delta entry.
    let specs = cfg.specs().unwrap();
    let full: Vec<usize> =
        specs.iter().map(computron::model::ModelSpec::param_bytes).collect();
    let footprint: usize = full
        .iter()
        .zip(&bases)
        .zip(&fracs)
        .map(|((&b, base), &f)| {
            if base.is_some() { computron::model::shard::scale_count(b, f) } else { b }
        })
        .sum();
    assert!(footprint > host.budget, "budget must force eviction pressure");
    assert!(full[0] + full[4] < host.budget, "both bases must fit host-resident");
    // Host config and base lineage survive a JSON round-trip.
    let back = SystemConfig::from_json(&cfg.to_json()).unwrap();
    assert_eq!(back.host, cfg.host, "host config changed in round-trip");
    assert_eq!(back.resolved_bases().unwrap(), bases);
    assert_eq!(back.models, cfg.models);
}

#[test]
fn legacy_presets_still_resolve_as_homogeneous_catalogs() {
    let dir = configs_dir();
    for name in [
        "swap_tp2_pp2.json",
        "workload_3model.json",
        "workload_6model.json",
        "slo_3model.json",
        "chunked_3model.json",
    ] {
        let cfg =
            SystemConfig::from_file(&dir.join(name)).unwrap_or_else(|e| panic!("{name}: {e}"));
        assert!(cfg.models.is_homogeneous(), "{name}: legacy presets are homogeneous");
        assert!(cfg.models.iter().all(|d| d.model == "opt-13b"), "{name}");
    }
    // The SLO preset exercises the scheduler + slos fields end-to-end.
    let cfg = SystemConfig::from_file(&dir.join("slo_3model.json")).unwrap();
    assert_eq!(cfg.engine.scheduler, SchedulerKind::Edf);
    assert_eq!(cfg.slos().as_deref(), Some(&[1.0, 3.0, 3.0][..]));
    assert_eq!(cfg.scenario.as_deref(), Some("bursty"));
    // The chunked preset exercises the swap-pipeline fields.
    let cfg = SystemConfig::from_file(&dir.join("chunked_3model.json")).unwrap();
    assert_eq!(cfg.engine.load_design, LoadDesign::ChunkedPipelined);
    assert_eq!(cfg.engine.chunk_layers, Some(2));
}

#[test]
fn hetero_preset_resolves_expected_catalog() {
    let cfg = SystemConfig::from_file(&configs_dir().join("hetero_4model.json")).unwrap();
    assert_eq!(cfg.num_models(), 4);
    assert!(!cfg.models.is_homogeneous());
    let archs: Vec<&str> = cfg.models.iter().map(|d| d.model.as_str()).collect();
    assert_eq!(archs, ["opt-1.3b", "opt-1.3b", "opt-6.7b", "opt-13b"]);
    assert_eq!(cfg.slos().as_deref(), Some(&[0.8, 0.8, 2.0, 4.0][..]));
    assert_eq!(cfg.models.rate_shares(), vec![4.0, 3.0, 2.0, 1.0]);
    assert_eq!(cfg.models.weights(), vec![2.0, 1.0, 1.0, 1.0]);
    assert_eq!(cfg.engine.load_design, LoadDesign::ChunkedPipelined);
    assert_eq!(cfg.scenario.as_deref(), Some("zipf"));
    // Per-model shard bytes are strictly increasing with architecture
    // size — the heterogeneity the hetero bench's oracles rely on.
    let shards = cfg.shard_bytes_per_model().unwrap();
    assert_eq!(shards[0], shards[1]);
    assert!(shards[1] < shards[2] && shards[2] < shards[3]);
}

#[test]
fn legacy_json_round_trips_through_the_catalog_shim() {
    // Legacy `num_models` + uniform `slo`.
    let legacy = Json::parse(
        r#"{"model":"opt-13b","num_models":3,"tp":2,"pp":2,
            "scheduler":"shed","slo":2.5,"resident_cap":2}"#,
    )
    .unwrap();
    let cfg = SystemConfig::from_json(&legacy).unwrap();
    assert_eq!(cfg.models, ModelCatalog::homogeneous("opt-13b", 3).with_uniform_slo(2.5));
    let back = SystemConfig::from_json(&cfg.to_json()).unwrap();
    assert_eq!(back.models, cfg.models);
    assert_eq!(back.engine.scheduler, SchedulerKind::Shed);

    // Legacy `slos` array.
    let legacy = Json::parse(
        r#"{"model":"opt-13b","num_models":3,"tp":2,"pp":2,"slos":[1.0,2.0,3.0]}"#,
    )
    .unwrap();
    let cfg = SystemConfig::from_json(&legacy).unwrap();
    assert_eq!(cfg.slos().as_deref(), Some(&[1.0, 2.0, 3.0][..]));
    let back = SystemConfig::from_json(&cfg.to_json()).unwrap();
    assert_eq!(back.slos().as_deref(), Some(&[1.0, 2.0, 3.0][..]));

    // Wrong-length legacy slos rejected at parse time.
    let bad = Json::parse(
        r#"{"model":"opt-13b","num_models":3,"tp":2,"pp":2,"slos":[1.0,2.0]}"#,
    )
    .unwrap();
    assert!(SystemConfig::from_json(&bad).is_err());
}

/// The planner-emitted preset (`computron plan --catalog
/// configs/hetero_4model.json --emit-config ...`, DESIGN.md §10): the
/// hetero_4model fleet re-laid-out as four dedicated tp2×pp1 groups on
/// an 8-GPU budget. Dedicated hosting keeps every group at or under
/// `resident_cap`, so the plan never swaps — the property the planner
/// converges on under overload (pinned end-to-end by
/// `benches/planner_suite.rs`).
#[test]
fn planned_preset_resolves_expected_placement() {
    let cfg = SystemConfig::from_file(&configs_dir().join("planned_hetero.json")).unwrap();
    // Same fleet as hetero_4model.json — only the placement differs.
    let base = SystemConfig::from_file(&configs_dir().join("hetero_4model.json")).unwrap();
    assert_eq!(cfg.models, base.models, "planned preset serves the hetero_4model fleet");
    assert_eq!(cfg.scenario.as_deref(), Some("zipf"));
    let p = cfg.placement.as_ref().expect("planned preset carries a placement");
    assert_eq!(p.router, computron::config::RouterKind::RoundRobin);
    assert_eq!(p.groups.len(), 4, "one dedicated group per model");
    assert_eq!(p.world(), 8, "partitions the full 8-GPU budget");
    for (m, g) in p.groups.iter().enumerate() {
        assert_eq!((g.parallel.tp, g.parallel.pp), (2, 1));
        assert_eq!(g.models, vec![m], "group {m} hosts exactly model {m}");
        assert!(
            g.models.len() <= cfg.engine.resident_cap,
            "dedicated hosting never exceeds the resident cap (no swapping)"
        );
    }
    // The preset builds a 4-group simulator directly.
    let (sys, _) = computron::sim::SimCluster::from_scenario(cfg, 2.0, 7).unwrap();
    assert_eq!(sys.num_groups(), 4);
}

/// The chaos quick-start preset (`computron simulate --faults
/// configs/chaos_spot.json`, DESIGN.md §11): the groups_2x2 fleet under
/// two staggered spot-preemption waves, with retries and the elastic
/// autoscaler armed.
#[test]
fn chaos_preset_resolves_expected_faults() {
    use computron::cluster::fault::FaultKind;

    let cfg = SystemConfig::from_file(&configs_dir().join("chaos_spot.json")).unwrap();
    let p = cfg.placement.as_ref().expect("chaos preset carries a placement");
    assert_eq!(p.router, computron::config::RouterKind::LeastLoaded);
    assert_eq!(p.groups.len(), 2, "waves alternate across two replicated groups");

    let plan = cfg.faults.as_ref().expect("chaos preset carries a fault plan");
    assert!(!plan.is_none());
    plan.validate(p.groups.len()).expect("plan targets in-range groups");
    // Two staggered preemption waves, each with a warning and a recovery:
    // group 1 first, then group 0 — never both at once.
    let preempts: Vec<usize> = plan
        .events
        .iter()
        .filter_map(|e| match e.kind {
            FaultKind::GroupPreempt { group, warning } => {
                assert!(warning > 0.0, "spot preemptions come with notice");
                Some(group)
            }
            _ => None,
        })
        .collect();
    assert_eq!(preempts, vec![1, 0]);
    let recovers =
        plan.events.iter().filter(|e| matches!(e.kind, FaultKind::GroupRecover { .. })).count();
    assert_eq!(recovers, 2, "every preempted group comes back");
    assert!(plan.retry.max_retries >= 1, "the quick-start demonstrates re-homing, not loss");
    assert!(plan.autoscale.is_some(), "the elastic controller is armed");
    // The resolved timeline interleaves drains before kills.
    let timeline = plan.timeline();
    assert_eq!(timeline.len(), 6, "2 x (drain + fail) + 2 recovers");
    assert!(timeline.windows(2).all(|w| w[0].0 <= w[1].0), "timeline is time-ordered");

    // The preset builds a faulted 2-group simulator directly.
    let (sys, _) = computron::sim::SimCluster::from_scenario(cfg, 2.0, 7).unwrap();
    assert_eq!(sys.num_groups(), 2);
}

#[test]
fn groups_preset_resolves_expected_placement() {
    let cfg = SystemConfig::from_file(&configs_dir().join("groups_2x2.json")).unwrap();
    assert_eq!(cfg.num_models(), 4);
    let p = cfg.placement.as_ref().expect("groups preset carries a placement");
    assert_eq!(p.router, computron::config::RouterKind::ResidentAffinity);
    assert_eq!(p.groups.len(), 2);
    for g in &p.groups {
        // Groups inherit the top-level grid and replicate the catalog.
        assert_eq!((g.parallel.tp, g.parallel.pp), (2, 2));
        assert_eq!(g.models, vec![0, 1, 2, 3]);
        assert_eq!(g.gpu_mem, None);
    }
    assert_eq!(p.world(), 8, "2 groups x 4 GPUs");
    assert_eq!(p.groups_for(3), vec![0, 1], "every model is replicated");
    assert_eq!(cfg.scenario.as_deref(), Some("zipf"));
    // The preset builds a 2-group simulator directly.
    let (sys, _) = computron::sim::SimCluster::from_scenario(cfg, 2.0, 7).unwrap();
    assert_eq!(sys.num_groups(), 2);
    assert_eq!(sys.router_name(), "resident-affinity");
}
