//! Property pins for the placement planner's search core (DESIGN.md
//! §10). The planner is simulator-in-the-loop, so these properties are
//! what make it trustworthy enough to emit checked-in presets:
//!
//! 1. *Feasibility*: every candidate the enumerator emits passes the
//!    full `SystemConfig::validate` placement gate (shard divisibility
//!    + per-group memory bound) and partitions exactly the GPU budget.
//! 2. *Never worse than greedy*: simulated annealing tracks best-so-far,
//!    so `plan.score >= plan.greedy_score` always.
//! 3. *Determinism*: a fixed seed reproduces the plan bit-for-bit —
//!    same spec, same score bits, same evaluation count.
//! 4. *Degeneracy*: on a homogeneous 1-model catalog with the budget
//!    equal to the base grid, the planner returns the legacy
//!    single-group spec bit-for-bit (`PlacementSpec::single`), because
//!    the base layout is enumerated first and score ties never displace
//!    the incumbent.
//! 5. *Worker independence*: scoring is batch-parallel (DESIGN.md §13)
//!    with every RNG draw on the single-threaded generate/fold path, so
//!    the plan is bit-for-bit identical at any scoring-pool width.

use computron::config::{
    ModelCatalog, ModelDeployment, Objective, PlacementSpec, PlannerConfig, SystemConfig,
};
use computron::coordinator::planner;

/// The group_scaling skewed hetero fleet: hot small models, cold tail.
fn hetero_fleet() -> ModelCatalog {
    ModelCatalog::new(vec![
        ModelDeployment::new("opt-1.3b").with_slo(1.0).with_rate_share(4.0),
        ModelDeployment::new("opt-1.3b").with_slo(1.0).with_rate_share(3.0),
        ModelDeployment::new("opt-2.7b").with_slo(1.0).with_rate_share(2.0),
        ModelDeployment::new("opt-6.7b").with_slo(1.0).with_rate_share(1.0),
    ])
}

fn hetero_base() -> SystemConfig {
    SystemConfig::hetero_experiment(hetero_fleet(), 2, 8)
}

/// Small, fast knobs for the search-property tests: 2 s scoring windows
/// and a 10-evaluation budget keep each `plan` call well under a second.
fn small_knobs(base: &SystemConfig, gpu_budget: usize, seed: u64) -> PlannerConfig {
    let mut knobs = PlannerConfig::for_config(base, gpu_budget);
    knobs.duration = 2.0;
    knobs.rate_scale = 8.0;
    knobs.eval_budget = 10;
    knobs.seed = seed;
    knobs
}

/// Property 1: every enumerated candidate is feasible under the full
/// config validation gate and uses exactly the GPU budget.
#[test]
fn every_enumerated_candidate_passes_validation() {
    let bases = [SystemConfig::workload_experiment(3, 2, 8), hetero_base()];
    for base in &bases {
        for budget in [4usize, 8] {
            let knobs = PlannerConfig::for_config(base, budget);
            let pool = planner::enumerate_candidates(base, &knobs);
            assert!(
                !pool.is_empty(),
                "budget {budget}: enumerator must emit at least one candidate"
            );
            for (i, spec) in pool.iter().enumerate() {
                assert_eq!(
                    spec.world(),
                    budget,
                    "budget {budget}, candidate {i}: must partition the full budget"
                );
                spec.validate(base.num_models()).unwrap_or_else(|e| {
                    panic!("budget {budget}, candidate {i}: structural validation: {e}")
                });
                let mut cfg = base.clone();
                cfg.placement = Some(spec.clone());
                cfg.validate().unwrap_or_else(|e| {
                    panic!("budget {budget}, candidate {i}: feasibility validation: {e}")
                });
            }
        }
    }
}

/// Property 2: the annealer tracks best-so-far, so the returned plan is
/// never worse than the greedy seed it started from.
#[test]
fn annealer_never_returns_worse_than_greedy_seed() {
    let base = hetero_base();
    for seed in [1u64, 7, 42] {
        let knobs = small_knobs(&base, 4, seed);
        let plan = planner::plan(&base, "zipf", &knobs).expect("plan succeeds");
        assert!(
            plan.score >= plan.greedy_score,
            "seed {seed}: plan score {} below greedy seed {}",
            plan.score,
            plan.greedy_score
        );
        assert!(
            plan.evals <= knobs.eval_budget,
            "seed {seed}: spent {} evals over the {} budget",
            plan.evals,
            knobs.eval_budget
        );
    }
}

/// Property 3: the planner is a pure function of (config, scenario,
/// knobs) — a fixed seed reproduces the plan bit-for-bit.
#[test]
fn fixed_seed_reproduces_the_plan_bit_for_bit() {
    let base = hetero_base();
    let knobs = small_knobs(&base, 4, 0xD5EED);
    let a = planner::plan(&base, "zipf", &knobs).expect("plan succeeds");
    let b = planner::plan(&base, "zipf", &knobs).expect("plan succeeds");
    assert_eq!(a.spec, b.spec, "specs differ across identical runs");
    assert_eq!(
        a.spec.to_json().to_string(),
        b.spec.to_json().to_string(),
        "serialized specs differ across identical runs"
    );
    assert_eq!(
        a.score.to_bits(),
        b.score.to_bits(),
        "scores differ across identical runs"
    );
    assert_eq!(a.greedy_spec, b.greedy_spec, "greedy seeds differ");
    assert_eq!(a.evals, b.evals, "evaluation counts differ");
    assert_eq!(a.enumerated, b.enumerated, "candidate pools differ");
}

/// Property 4: a homogeneous 1-model catalog with the budget equal to
/// the base grid degenerates to the legacy single-group spec
/// bit-for-bit. Every candidate ties on goodput (no SLOs, no drops, all
/// arrivals complete), and ties never displace the first-enumerated
/// incumbent — which is the base layout by construction.
#[test]
fn single_model_catalog_degenerates_to_legacy_spec() {
    let base = SystemConfig::workload_experiment(1, 1, 8);
    let mut knobs = PlannerConfig::for_config(&base, base.parallel.world());
    knobs.duration = 2.0;
    knobs.rate_scale = 1.0;
    knobs.eval_budget = 8;
    knobs.seed = 3;
    knobs.objective = Objective::Goodput;
    let plan = planner::plan(&base, "uniform", &knobs).expect("plan succeeds");
    let legacy = PlacementSpec::single(base.parallel, 1);
    assert_eq!(
        plan.spec, legacy,
        "1-model catalog must degenerate to the legacy single-group spec"
    );
    assert_eq!(
        plan.spec.to_json().to_string(),
        legacy.to_json().to_string(),
        "degenerate spec must serialize bit-for-bit like the legacy shim"
    );
}

/// Property 5: the scoring-pool width never changes the plan. Proposal
/// batches are a fixed size (worker-count independent), every RNG draw
/// happens on the single-threaded generate/fold path, and results fold
/// in proposal order — so `workers = 1` and `workers = 4` must agree
/// bit-for-bit on spec, score, greedy seed, and evaluation count.
#[test]
fn scoring_pool_width_never_changes_the_plan() {
    let base = hetero_base();
    for seed in [0xD5EEDu64, 11] {
        let mut knobs = small_knobs(&base, 8, seed);
        knobs.workers = 1;
        let one = planner::plan(&base, "zipf", &knobs).expect("plan succeeds");
        knobs.workers = 4;
        let four = planner::plan(&base, "zipf", &knobs).expect("plan succeeds");
        assert_eq!(one.spec, four.spec, "seed {seed}: specs differ across pool widths");
        assert_eq!(
            one.spec.to_json().to_string(),
            four.spec.to_json().to_string(),
            "seed {seed}: serialized specs differ across pool widths"
        );
        assert_eq!(
            one.score.to_bits(),
            four.score.to_bits(),
            "seed {seed}: scores differ across pool widths"
        );
        assert_eq!(one.greedy_spec, four.greedy_spec, "seed {seed}: greedy seeds differ");
        assert_eq!(
            one.greedy_score.to_bits(),
            four.greedy_score.to_bits(),
            "seed {seed}: greedy scores differ"
        );
        assert_eq!(one.evals, four.evals, "seed {seed}: evaluation counts differ");
        assert_eq!(one.enumerated, four.enumerated, "seed {seed}: candidate pools differ");
    }
}
