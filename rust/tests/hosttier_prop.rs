//! Host-memory-tier property tests (DESIGN.md §12):
//!
//! 1. **Policy reference models** — every registered host-eviction
//!    policy (`lru` / `lfu` / `weighted-cost`) is replayed over random
//!    access traces against a straightforward reference: the victim is
//!    always drawn from the candidate set, LRU picks the least recently
//!    fetched, LFU the least frequently fetched, weighted-cost the
//!    minimum `(accesses + 1) · refetch_cost / bytes`.
//! 2. **Tier accounting invariants** — random fetch/admit traces over
//!    random catalogs (with delta-form variants) never exceed the pinned
//!    budget, conserve NVMe bytes and hit/miss/eviction/overflow counts
//!    against an external ledger, and never evict a base from under a
//!    resident delta-form dependent.
//! 3. **Delta-plan conservation** — `split_delta` partitions exactly and
//!    `delta_chunk_plan` preserves chunk count while its byte/message
//!    totals equal `scale_count` of the full totals exactly.
//! 4. **Transparency pin** — a warm-started host tier with an effectively
//!    infinite budget reproduces the no-host-config runs bit-for-bit
//!    across the full scenario registry, both load designs, and every
//!    host policy; the no-host runs carry no tier artifacts at all.

use computron::cluster::hosttier::{
    make_host_policy, HostCandidate, HostEvictionPolicy, HostPolicyKind, HostTier, SwapTier,
};
use computron::cluster::LinkModel;
use computron::config::{HostConfig, LoadDesign, SystemConfig};
use computron::model::shard::{delta_chunk_plan, scale_count, split_delta, ChunkSpec};
use computron::sim::{SimReport, SimSystem};
use computron::util::prop;
use computron::util::rng::Rng;
use computron::workload::scenarios;

// ---------------------------------------------------------------------
// 1. Policy reference models
// ---------------------------------------------------------------------

/// One randomized policy-trace event. `Access` may hit a non-resident
/// model (the tier calls `on_access` on every fetch, cold or warm);
/// `Insert`/`Evict` are well-formed against the resident set.
#[derive(Clone, Debug)]
enum Op {
    Insert(usize),
    Access(usize),
    Evict(usize),
}

fn gen_trace(rng: &mut Rng, num_models: usize, len: usize) -> Vec<Op> {
    let mut resident: Vec<usize> = Vec::new();
    let mut ops = Vec::new();
    for _ in 0..len {
        let roll = rng.f64();
        if resident.is_empty() || roll < 0.3 {
            let m = rng.index(num_models);
            if !resident.contains(&m) {
                resident.push(m);
                ops.push(Op::Insert(m));
            }
        } else if roll < 0.8 {
            ops.push(Op::Access(rng.index(num_models)));
        } else {
            let i = rng.index(resident.len());
            ops.push(Op::Evict(resident.remove(i)));
        }
    }
    ops
}

/// Reference state after a trace: resident set, last fetch time (insert
/// counts as a touch), lifetime access counts (never reset on eviction —
/// host frequency is per model, not per residency stint).
struct Reference {
    resident: Vec<usize>,
    last: Vec<f64>,
    counts: Vec<u64>,
}

fn replay(policy: &mut dyn HostEvictionPolicy, ops: &[Op], num_models: usize) -> Reference {
    let mut r = Reference {
        resident: Vec::new(),
        last: vec![f64::NEG_INFINITY; num_models],
        counts: vec![0; num_models],
    };
    let mut now = 0.0;
    for op in ops {
        now += 1.0;
        match *op {
            Op::Insert(m) => {
                policy.on_insert(m, now);
                r.resident.push(m);
                r.last[m] = r.last[m].max(now);
            }
            Op::Access(m) => {
                policy.on_access(m, now);
                r.last[m] = now;
                r.counts[m] += 1;
            }
            Op::Evict(m) => {
                policy.on_evict(m);
                r.resident.retain(|&x| x != m);
            }
        }
    }
    r
}

/// Candidate records for the reference's resident set, with fixed
/// per-model sizes and refetch costs shared by policy and reference.
fn candidates(resident: &[usize], bytes: &[usize], cost: &[f64]) -> Vec<HostCandidate> {
    resident
        .iter()
        .map(|&m| HostCandidate { model: m, bytes: bytes[m], refetch_cost: cost[m] })
        .collect()
}

fn gen_catalog_costs(rng: &mut Rng, n: usize) -> (Vec<usize>, Vec<f64>) {
    let bytes: Vec<usize> = (0..n).map(|_| prop::usize_in(rng, 1, 1000)).collect();
    let cost: Vec<f64> = (0..n).map(|_| prop::f64_in(rng, 0.01, 10.0)).collect();
    (bytes, cost)
}

#[test]
fn host_victim_always_from_candidates_all_policies() {
    for kind in HostPolicyKind::all() {
        prop::check(
            &format!("host-victim-in-candidates-{}", kind.name()),
            |rng: &mut Rng| {
                let n = prop::usize_in(rng, 2, 8);
                let ops = gen_trace(rng, n, prop::usize_in(rng, 1, 64));
                let (bytes, cost) = gen_catalog_costs(rng, n);
                let seed = rng.next_u64();
                (n, ops, bytes, cost, seed)
            },
            |(n, ops, bytes, cost, seed)| {
                let mut policy = make_host_policy(kind, *n);
                let reference = replay(policy.as_mut(), ops, *n);
                if policy.victim(&[]).is_some() {
                    return Err("victim from empty candidate set".into());
                }
                let mut rng = Rng::seeded(seed.wrapping_add(1));
                for _ in 0..8 {
                    let subset: Vec<usize> =
                        reference.resident.iter().copied().filter(|_| rng.f64() < 0.7).collect();
                    let cands = candidates(&subset, bytes, cost);
                    match policy.victim(&cands) {
                        None if cands.is_empty() => {}
                        None => return Err("no victim despite candidates".into()),
                        Some(v) if subset.contains(&v) => {}
                        Some(v) => return Err(format!("victim {v} not in {subset:?}")),
                    }
                }
                Ok(())
            },
        );
    }
}

#[test]
fn host_lru_picks_least_recently_fetched() {
    prop::check(
        "host-lru-least-recent",
        |rng: &mut Rng| {
            let n = prop::usize_in(rng, 2, 8);
            let ops = gen_trace(rng, n, prop::usize_in(rng, 4, 96));
            let (bytes, cost) = gen_catalog_costs(rng, n);
            (n, ops, bytes, cost)
        },
        |(n, ops, bytes, cost)| {
            let mut policy = make_host_policy(HostPolicyKind::Lru, *n);
            let reference = replay(policy.as_mut(), ops, *n);
            if reference.resident.is_empty() {
                return Ok(());
            }
            let expected = reference
                .resident
                .iter()
                .copied()
                .min_by(|&a, &b| reference.last[a].total_cmp(&reference.last[b]).then(a.cmp(&b)))
                .unwrap();
            let got = policy.victim(&candidates(&reference.resident, bytes, cost)).unwrap();
            if got != expected {
                return Err(format!(
                    "LRU chose {got}, expected {expected} (last {:?})",
                    reference.last
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn host_lfu_picks_least_frequently_fetched() {
    prop::check(
        "host-lfu-least-frequent",
        |rng: &mut Rng| {
            let n = prop::usize_in(rng, 2, 8);
            let ops = gen_trace(rng, n, prop::usize_in(rng, 4, 96));
            let (bytes, cost) = gen_catalog_costs(rng, n);
            (n, ops, bytes, cost)
        },
        |(n, ops, bytes, cost)| {
            let mut policy = make_host_policy(HostPolicyKind::Lfu, *n);
            let reference = replay(policy.as_mut(), ops, *n);
            if reference.resident.is_empty() {
                return Ok(());
            }
            let expected = reference
                .resident
                .iter()
                .copied()
                .min_by_key(|&m| (reference.counts[m], m))
                .unwrap();
            let got = policy.victim(&candidates(&reference.resident, bytes, cost)).unwrap();
            if got != expected {
                return Err(format!(
                    "LFU chose {got}, expected {expected} (counts {:?})",
                    reference.counts
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn host_weighted_cost_minimizes_refetch_pain_per_byte() {
    prop::check(
        "host-weighted-cost-score",
        |rng: &mut Rng| {
            let n = prop::usize_in(rng, 2, 8);
            let ops = gen_trace(rng, n, prop::usize_in(rng, 4, 96));
            let (bytes, cost) = gen_catalog_costs(rng, n);
            (n, ops, bytes, cost)
        },
        |(n, ops, bytes, cost)| {
            let mut policy = make_host_policy(HostPolicyKind::WeightedCost, *n);
            let reference = replay(policy.as_mut(), ops, *n);
            if reference.resident.is_empty() {
                return Ok(());
            }
            let score =
                |m: usize| (reference.counts[m] + 1) as f64 * cost[m] / bytes[m].max(1) as f64;
            let expected = reference
                .resident
                .iter()
                .copied()
                .min_by(|&a, &b| score(a).total_cmp(&score(b)).then(a.cmp(&b)))
                .unwrap();
            let got = policy.victim(&candidates(&reference.resident, bytes, cost)).unwrap();
            if got != expected {
                return Err(format!(
                    "weighted-cost chose {got} (score {}), expected {expected} (score {})",
                    score(got),
                    score(expected)
                ));
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------
// 2. Tier accounting invariants
// ---------------------------------------------------------------------

/// One randomized tier event. `evict_mask` bit `m` marks model `m`
/// evictable this step (the simulator's "not GPU-resident" predicate is
/// an arbitrary caller-supplied filter from the tier's point of view).
#[derive(Clone, Debug)]
enum TierOp {
    Fetch { model: usize, chunks: usize, evict_mask: u64 },
    Admit { model: usize, evict_mask: u64 },
}

/// Random single-level variant catalog: every base is itself baseless,
/// mirroring `SystemConfig::resolved_bases` (a base may not have a base).
#[derive(Clone, Debug)]
struct Catalog {
    bases: Vec<Option<usize>>,
    full: Vec<usize>,
    delta: Vec<usize>,
}

fn gen_tier_catalog(rng: &mut Rng, n: usize) -> Catalog {
    let mut cat =
        Catalog { bases: vec![None; n], full: vec![0; n], delta: vec![0; n] };
    for m in 0..n {
        cat.full[m] = prop::usize_in(rng, 40, 200);
        let baseless: Vec<usize> = (0..m).filter(|&j| cat.bases[j].is_none()).collect();
        if !baseless.is_empty() && rng.f64() < 0.4 {
            cat.bases[m] = Some(baseless[rng.index(baseless.len())]);
            cat.delta[m] = scale_count(cat.full[m], prop::f64_in(rng, 0.1, 0.9));
        } else {
            cat.delta[m] = cat.full[m];
        }
    }
    cat
}

#[test]
fn tier_accounting_matches_external_ledger_under_random_traces() {
    prop::check(
        "host-tier-ledger",
        |rng: &mut Rng| {
            let n = prop::usize_in(rng, 2, 6);
            let cat = gen_tier_catalog(rng, n);
            let budget = prop::usize_in(rng, 80, 500);
            let kind = prop::choice(rng, &HostPolicyKind::all());
            let ops: Vec<TierOp> = (0..prop::usize_in(rng, 10, 80))
                .map(|_| {
                    let model = rng.index(n);
                    let evict_mask = rng.next_u64();
                    if rng.f64() < 0.8 {
                        TierOp::Fetch { model, chunks: prop::usize_in(rng, 1, 4), evict_mask }
                    } else {
                        TierOp::Admit { model, evict_mask }
                    }
                })
                .collect();
            (cat, budget, kind, ops)
        },
        |(cat, budget, kind, ops)| {
            let n = cat.full.len();
            let nvme = LinkModel { alpha: 0.001, bandwidth: 100.0, pageable_copy_bw: f64::INFINITY };
            let mut tier = HostTier::new(
                *budget,
                *kind,
                nvme,
                cat.bases.clone(),
                cat.full.clone(),
                cat.delta.clone(),
            );
            // External ledger, updated from observable outcomes only.
            let mut delta_form = vec![false; n];
            let (mut hits, mut misses, mut evictions, mut overflows) = (0u64, 0u64, 0u64, 0u64);
            let (mut nvme_bytes, mut delta_saved) = (0u64, 0u64);
            let mut max_used = 0usize;
            let mut now = 0.0;
            for op in ops {
                now += 1.0;
                let before: Vec<bool> = (0..n).map(|m| tier.is_resident(m)).collect();
                match *op {
                    TierOp::Fetch { model, chunks, evict_mask } => {
                        let evictable = |m: usize| (evict_mask >> m) & 1 == 1;
                        let out = tier.fetch(model, now, chunks, &evictable);
                        if before[model] {
                            if out.tier != SwapTier::HostHit {
                                return Err(format!("resident model {model} missed"));
                            }
                            if out.host_delta != delta_form[model] {
                                return Err(format!("hit on {model} misreported its form"));
                            }
                            if !out.gates.is_empty() {
                                return Err("host hit must be ungated".into());
                            }
                            hits += 1;
                        } else {
                            if out.tier != SwapTier::NvmeMiss {
                                return Err(format!("cold model {model} hit"));
                            }
                            misses += 1;
                            // Delta-form admission iff the base was warm at
                            // fetch time; full-form (and full staging) else.
                            let base_warm =
                                matches!(cat.bases[model], Some(b) if before[b]);
                            if out.host_delta != base_warm {
                                return Err(format!(
                                    "miss on {model}: host_delta {} but base_warm {base_warm}",
                                    out.host_delta
                                ));
                            }
                            let staged =
                                if out.host_delta { cat.delta[model] } else { cat.full[model] };
                            nvme_bytes += staged as u64;
                            if out.gates.len() != chunks.max(1) {
                                return Err(format!(
                                    "{} gates for {} chunks",
                                    out.gates.len(),
                                    chunks
                                ));
                            }
                            if out.gates.windows(2).any(|w| w[0] > w[1]) || out.gates[0] < now {
                                return Err(format!("unsorted gates {:?}", out.gates));
                            }
                            if tier.is_resident(model) {
                                delta_form[model] = out.host_delta;
                                if out.host_delta {
                                    delta_saved += (cat.full[model] - cat.delta[model]) as u64;
                                }
                            } else {
                                overflows += 1;
                            }
                        }
                    }
                    TierOp::Admit { model, evict_mask } => {
                        let evictable = |m: usize| (evict_mask >> m) & 1 == 1;
                        let admitted = tier.admit(model, now, &evictable);
                        if before[model] {
                            if !admitted {
                                return Err(format!("resident {model} refused re-admission"));
                            }
                        } else if admitted {
                            delta_form[model] = false; // offload write-back is full-form
                        } else {
                            overflows += 1;
                        }
                        if admitted != tier.is_resident(model) {
                            return Err("admit return disagrees with residency".into());
                        }
                    }
                }
                // Evictions are residency transitions we did not request.
                for m in 0..n {
                    if before[m] && !tier.is_resident(m) {
                        evictions += 1;
                        delta_form[m] = false;
                    }
                }
                // Budget, occupancy, and base-pinning invariants.
                let expected_used: usize = (0..n)
                    .filter(|&m| tier.is_resident(m))
                    .map(|m| if delta_form[m] { cat.delta[m] } else { cat.full[m] })
                    .sum();
                if tier.pool().used() != expected_used {
                    return Err(format!(
                        "used {} != ledger {expected_used}",
                        tier.pool().used()
                    ));
                }
                if tier.pool().used() > *budget {
                    return Err(format!("pinned {} over budget {budget}", tier.pool().used()));
                }
                if tier.resident_count() != (0..n).filter(|&m| tier.is_resident(m)).count() {
                    return Err("resident_count disagrees with is_resident".into());
                }
                max_used = max_used.max(tier.pool().used());
                for v in 0..n {
                    if tier.is_resident(v) && delta_form[v] {
                        let b = cat.bases[v].expect("delta form without base");
                        if !tier.is_resident(b) {
                            return Err(format!(
                                "base {b} evicted under resident delta dependent {v}"
                            ));
                        }
                    }
                }
            }
            let s = tier.stats();
            if (s.hits, s.misses, s.evictions, s.overflows) != (hits, misses, evictions, overflows)
            {
                return Err(format!(
                    "stats {:?} != ledger (h {hits}, m {misses}, e {evictions}, o {overflows})",
                    (s.hits, s.misses, s.evictions, s.overflows)
                ));
            }
            if s.nvme_bytes != nvme_bytes || s.delta_bytes_saved != delta_saved {
                return Err(format!(
                    "bytes (nvme {}, saved {}) != ledger (nvme {nvme_bytes}, saved {delta_saved})",
                    s.nvme_bytes, s.delta_bytes_saved
                ));
            }
            if tier.pool().high_water() < max_used || tier.pool().high_water() > *budget {
                return Err(format!(
                    "high water {} outside [{max_used}, {budget}]",
                    tier.pool().high_water()
                ));
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------
// 3. Delta-plan conservation
// ---------------------------------------------------------------------

#[test]
fn split_delta_partitions_exactly() {
    prop::check(
        "split-delta-partition",
        |rng: &mut Rng| {
            (prop::usize_in(rng, 0, 1_000_000_000), prop::f64_in(rng, 0.001, 1.0))
        },
        |(bytes, f)| {
            let (base, delta) = split_delta(*bytes, *f);
            if base + delta != *bytes {
                return Err(format!("{base} + {delta} != {bytes}"));
            }
            if delta != scale_count(*bytes, *f) {
                return Err("delta component disagrees with scale_count".into());
            }
            if *bytes > 0 && delta == 0 {
                return Err("non-empty shard produced an empty delta".into());
            }
            Ok(())
        },
    );
}

#[test]
fn delta_chunk_plan_conserves_totals_exactly() {
    prop::check(
        "delta-plan-conservation",
        |rng: &mut Rng| {
            let n = prop::usize_in(rng, 1, 8);
            let plan: Vec<ChunkSpec> = (0..n)
                .map(|_| ChunkSpec {
                    layers: prop::usize_in(rng, 1, 4),
                    messages: prop::usize_in(rng, 1, 64),
                    bytes: prop::usize_in(rng, 1, 10_000),
                })
                .collect();
            (plan, prop::f64_in(rng, 0.02, 1.0))
        },
        |(plan, f)| {
            let n = plan.len();
            let total_bytes: usize = plan.iter().map(|c| c.bytes).sum();
            let total_msgs: usize = plan.iter().map(|c| c.messages).sum();
            let (dbytes, dmsgs) = (scale_count(total_bytes, *f), scale_count(total_msgs, *f));
            if dbytes < n || dmsgs < n {
                // Infeasible spread: the simulator falls back to a
                // full-form load rather than calling delta_chunk_plan.
                return Ok(());
            }
            let dp = delta_chunk_plan(plan, *f);
            if dp.len() != n {
                return Err(format!("chunk count changed: {} != {n}", dp.len()));
            }
            let got_bytes: usize = dp.iter().map(|c| c.bytes).sum();
            let got_msgs: usize = dp.iter().map(|c| c.messages).sum();
            if got_bytes != dbytes || got_msgs != dmsgs {
                return Err(format!(
                    "totals ({got_bytes} B, {got_msgs} msgs) != scale_count ({dbytes}, {dmsgs})"
                ));
            }
            for (full, delta) in plan.iter().zip(&dp) {
                if delta.bytes == 0 || delta.messages == 0 {
                    return Err(format!("empty delta chunk in {dp:?}"));
                }
                if delta.layers != full.layers {
                    return Err("layer counts must be preserved per chunk".into());
                }
                if delta.bytes > full.bytes || delta.messages > full.messages {
                    return Err(format!("delta chunk exceeds its full chunk: {dp:?}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn delta_fraction_one_is_the_identity_plan() {
    let plan = vec![
        ChunkSpec { layers: 2, messages: 7, bytes: 1000 },
        ChunkSpec { layers: 2, messages: 5, bytes: 900 },
        ChunkSpec { layers: 1, messages: 3, bytes: 128 },
    ];
    assert_eq!(delta_chunk_plan(&plan, 1.0), plan);
}

// ---------------------------------------------------------------------
// 4. Transparency pin: warm infinite host tier ≡ no host config
// ---------------------------------------------------------------------

fn base_cfg(design: LoadDesign) -> SystemConfig {
    let mut cfg = SystemConfig::workload_experiment(3, 2, 8);
    cfg.engine.load_design = design;
    cfg
}

fn run_scenario(cfg: SystemConfig, name: &str) -> SimReport {
    let mut cfg = cfg;
    cfg.scenario = Some(name.to_string());
    let (sys, _) = SimSystem::from_scenario(cfg, 5.0, 0xC1_0572).unwrap();
    sys.run()
}

fn assert_bit_identical(tag: &str, a: &SimReport, b: &SimReport) {
    assert_eq!(a.requests, b.requests, "{tag}: request records diverged");
    assert_eq!(a.swaps, b.swaps, "{tag}: swap records diverged");
    assert_eq!(a.drops, b.drops, "{tag}: drop records diverged");
    assert_eq!(a.events, b.events, "{tag}: event counts diverged");
    assert_eq!(a.mem_high_water, b.mem_high_water, "{tag}: memory diverged");
    assert_eq!(a.h2d_bytes, b.h2d_bytes, "{tag}: H2D traffic diverged");
    assert_eq!(a.d2h_bytes, b.d2h_bytes, "{tag}: D2H traffic diverged");
    assert_eq!(a.swap_stats, b.swap_stats, "{tag}: swap stats diverged");
    assert_eq!(a.sim_end, b.sim_end, "{tag}: end times diverged");
}

#[test]
fn warm_infinite_host_tier_is_transparent_across_registry() {
    for design in [LoadDesign::AsyncPipelined, LoadDesign::ChunkedPipelined] {
        for &name in scenarios::names() {
            let legacy = run_scenario(base_cfg(design), name);
            // No host config → no tier artifacts anywhere in the report.
            assert!(legacy.host.is_empty(), "{name}: host reports without a host config");
            assert!(legacy.groups.iter().all(|g| g.host.is_none() && g.delta_bytes_saved == 0));
            assert!(
                legacy.swaps.iter().all(|s| s.tier == SwapTier::HostHit && s.delta_bytes_saved == 0),
                "{name}: legacy swaps must default to the warm-host tier"
            );
            for kind in HostPolicyKind::all() {
                let mut cfg = base_cfg(design);
                cfg.host = Some(HostConfig {
                    budget: 1 << 60,
                    policy: kind,
                    warm_start: true,
                    ..HostConfig::default()
                });
                let warm = run_scenario(cfg, name);
                let tag = format!("{name}/{}/{}", design.name(), kind.name());
                assert_bit_identical(&tag, &legacy, &warm);
                // The tier saw every swap-in and served all of them warm.
                assert_eq!(warm.host.len(), 1, "{tag}: one per-group tier");
                let h = &warm.host[0];
                assert_eq!(h.policy, kind.name(), "{tag}");
                assert_eq!(h.stats.misses, 0, "{tag}: warm start may never miss");
                assert_eq!(h.stats.evictions, 0, "{tag}: infinite budget never evicts");
                assert_eq!(h.stats.nvme_bytes, 0, "{tag}: no staging traffic");
                assert!((h.hit_rate() - 1.0).abs() < 1e-12, "{tag}");
                assert!(
                    !legacy.swaps.is_empty() || h.stats.hits == 0,
                    "{tag}: hits without swaps"
                );
            }
        }
    }
}
