//! System-level property tests for the layer-granular chunked swap
//! pipeline (DESIGN.md §6):
//!
//! 1. Conservation: chunked transfers move exactly the bytes the
//!    monolithic design moves, per GPU and per direction.
//! 2. Equivalence: `chunk_layers >= layers-per-stage` (a one-chunk plan)
//!    reproduces the monolithic async design's records and event counts
//!    bit-for-bit, across the whole scenario registry.
//! 3. Win: with real chunking, cold-start latency strictly improves on
//!    the §5.1 worst case and the §5.2 workload while every engine
//!    invariant (no violations, no OOM, cap respected, swap accounting)
//!    still holds.
//! 4. Memory: with both directions chunking, the per-GPU high-water mark
//!    stays within cap shards plus one chunk of slack.

use computron::config::{LoadDesign, SystemConfig};
use computron::coordinator::engine::RequestRecord;
use computron::model::{catalog, max_shard_bytes};
use computron::sim::{Driver, SimReport, SimSystem};
use computron::workload::scenarios;

fn chunked(mut cfg: SystemConfig, chunk_layers: Option<usize>) -> SystemConfig {
    cfg.engine.load_design = LoadDesign::ChunkedPipelined;
    cfg.engine.chunk_layers = chunk_layers;
    cfg
}

fn run_scenario(cfg: SystemConfig, name: &str, duration: f64) -> SimReport {
    let mut cfg = cfg;
    cfg.scenario = Some(name.to_string());
    let (sys, _) = SimSystem::from_scenario(cfg, duration, 0xC114_7E).unwrap();
    sys.run()
}

fn run_swap_worst_case(cfg: SystemConfig, total: usize) -> SimReport {
    let mut sys = SimSystem::new(cfg, Driver::AlternatingBlocking {
        models: 2,
        input_len: 2,
        total,
    })
    .unwrap();
    sys.preload(&[1]);
    sys.run()
}

fn mean_latency(r: &SimReport) -> f64 {
    r.requests.iter().map(RequestRecord::latency).sum::<f64>() / r.requests.len() as f64
}

#[test]
fn chunked_moves_exactly_the_monolithic_bytes() {
    for chunk_layers in [Some(1), Some(4), None] {
        let mono = run_swap_worst_case(SystemConfig::swap_experiment(2, 2), 8);
        let chnk =
            run_swap_worst_case(chunked(SystemConfig::swap_experiment(2, 2), chunk_layers), 8);
        assert_eq!(mono.h2d_bytes, chnk.h2d_bytes, "chunk_layers={chunk_layers:?}");
        assert_eq!(mono.d2h_bytes, chnk.d2h_bytes, "chunk_layers={chunk_layers:?}");
        assert_eq!(mono.requests.len(), chnk.requests.len());
    }
}

#[test]
fn one_chunk_plan_reproduces_monolithic_across_registry() {
    // The equivalence invariant that keeps the paper-figure benches
    // honest: chunk_layers = "all" must be the monolithic design
    // bit-for-bit — same request records, same swap records, same event
    // counts — on every scenario in the registry.
    for &name in scenarios::names() {
        let mono = run_scenario(SystemConfig::workload_experiment(3, 2, 8), name, 8.0);
        let one = run_scenario(
            chunked(SystemConfig::workload_experiment(3, 2, 8), Some(1_000_000)),
            name,
            8.0,
        );
        assert_eq!(mono.requests, one.requests, "{name}: request records diverged");
        assert_eq!(mono.swaps, one.swaps, "{name}: swap records diverged");
        assert_eq!(mono.events, one.events, "{name}: event counts diverged");
        assert_eq!(mono.mem_high_water, one.mem_high_water, "{name}: memory diverged");
    }
}

#[test]
fn chunked_improves_cold_start_on_worst_case() {
    for (tp, pp) in [(1usize, 1usize), (2, 2)] {
        let mono = run_swap_worst_case(SystemConfig::swap_experiment(tp, pp), 8);
        let chnk = run_swap_worst_case(chunked(SystemConfig::swap_experiment(tp, pp), None), 8);
        assert!(
            mean_latency(&chnk) < mean_latency(&mono),
            "tp={tp} pp={pp}: chunked {} vs monolithic {}",
            mean_latency(&chnk),
            mean_latency(&mono)
        );
        assert_eq!(chnk.violations, 0);
        assert_eq!(chnk.oom_events, 0);
    }
}

#[test]
fn chunked_preserves_invariants_across_registry() {
    for &name in scenarios::names() {
        let r = run_scenario(chunked(SystemConfig::workload_experiment(3, 2, 8), None), name, 8.0);
        assert_eq!(r.violations, 0, "{name}: load-dependency violations");
        assert_eq!(r.oom_events, 0, "{name}: OOM events");
        let s = r.swap_stats;
        assert_eq!(
            s.loads_started,
            s.loads_completed + s.loads_cancelled,
            "{name}: loads did not drain"
        );
        assert_eq!(s.offloads_started, s.offloads_completed, "{name}: offloads did not drain");
        assert_eq!(r.swaps.len() as u64, s.loads_completed + s.loads_cancelled);
        // Completed swaps carry sane chunk metrics.
        for sw in r.swaps.iter().filter(|sw| !sw.cancelled) {
            assert!(sw.time_to_first_chunk > 0.0, "{name}: ttfc must be positive");
            assert!(
                sw.time_to_first_chunk <= sw.duration() + 1e-9,
                "{name}: ttfc exceeds swap duration"
            );
            assert!(
                (0.0..=1.0).contains(&sw.overlap_fraction),
                "{name}: overlap fraction out of range"
            );
        }
    }
}

#[test]
fn chunked_high_water_within_cap_plus_chunk() {
    // Worst case with single-layer chunks in both directions: the victim
    // drains chunk-by-chunk while the incoming model fills. Peak memory
    // must stay within one shard (cap = 1) plus a chunk of slack.
    let r = run_swap_worst_case(chunked(SystemConfig::swap_experiment(1, 1), Some(1)), 8);
    assert_eq!(r.oom_events, 0);
    let spec = catalog::opt("opt-13b").unwrap();
    let shard = max_shard_bytes(&spec, 1, 1).unwrap();
    let chunk_slack = spec.param_bytes() / 40 * 2;
    for &hw in &r.mem_high_water {
        assert!(hw <= shard + chunk_slack, "high water {hw} vs shard {shard}");
    }

    // And on the §5.2 grid (cap 2, TP=2 PP=2) across a busy scenario.
    let r = run_scenario(
        chunked(SystemConfig::workload_experiment(3, 2, 8), Some(2)),
        "uniform",
        8.0,
    );
    assert_eq!(r.oom_events, 0);
    let shard = max_shard_bytes(&spec, 2, 2).unwrap();
    for &hw in &r.mem_high_water {
        assert!(hw <= 2 * shard + shard / 4, "high water {hw} vs 2x shard {shard}");
    }
}

#[test]
fn chunked_fcfs_equals_edf_without_slos() {
    // The chunked pipeline composes with the scheduler registry: under
    // infinite SLOs edf degenerates to fcfs exactly as in the monolithic
    // design, and shed never drops.
    use computron::config::SchedulerKind;
    let run = |kind: SchedulerKind| {
        let mut cfg = chunked(SystemConfig::workload_experiment(3, 2, 8), None);
        cfg.engine.scheduler = kind;
        run_scenario(cfg, "bursty", 8.0)
    };
    let fcfs = run(SchedulerKind::Fcfs);
    let edf = run(SchedulerKind::Edf);
    let shed = run(SchedulerKind::Shed);
    assert_eq!(fcfs.requests, edf.requests);
    assert_eq!(fcfs.swaps, edf.swaps);
    assert_eq!(fcfs.events, edf.events);
    assert!(shed.drops.is_empty(), "infinite SLOs are always feasible");
}
