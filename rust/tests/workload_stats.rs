//! Statistical tests for the workload generators: configured rates/CVs
//! are realized within tolerance, Zipf popularity is monotone in rank,
//! scenario-specific shapes (on/off burstiness, diurnal peaks, flash
//! crowds) are present, and every generator is deterministic under a
//! fixed seed.

use computron::util::rng::Rng;
use computron::workload::scenarios::{
    self, DiurnalWorkload, FlashCrowdWorkload, MarkovOnOffWorkload, ScenarioParams, WorkloadGen,
    ZipfWorkload,
};
use computron::workload::GammaWorkload;

fn mean_and_cv(gaps: &[f64]) -> (f64, f64) {
    let n = gaps.len() as f64;
    let mean = gaps.iter().sum::<f64>() / n;
    let var = gaps.iter().map(|g| (g - mean) * (g - mean)).sum::<f64>() / n;
    (mean, var.sqrt() / mean)
}

#[test]
fn gamma_interarrival_mean_and_cv_match_config() {
    for &(rate, cv) in &[(4.0, 0.25), (4.0, 1.0), (4.0, 4.0)] {
        let w = GammaWorkload {
            rates: vec![rate],
            cv,
            duration: 8000.0,
            input_len: 8,
            warmup: 0,
            seed: 0x57A7,
        };
        let arr = w.generate();
        let gaps: Vec<f64> = arr.windows(2).map(|p| p[1].at - p[0].at).collect();
        assert!(gaps.len() > 10_000, "need a large sample, got {}", gaps.len());
        let (mean, cv_est) = mean_and_cv(&gaps);
        assert!(
            (mean - 1.0 / rate).abs() / (1.0 / rate) < 0.10,
            "cv={cv}: mean gap {mean} vs configured {}",
            1.0 / rate
        );
        assert!(
            (cv_est - cv).abs() / cv < 0.15,
            "configured cv={cv}, realized {cv_est}"
        );
    }
}

#[test]
fn zipf_frequencies_monotone_in_rank() {
    let params = ScenarioParams {
        num_models: 5,
        duration: 600.0,
        warmup: 0,
        ..ScenarioParams::new(5, 0x21FF)
    };
    let z = ZipfWorkload::new(params);
    let arr = z.generate();
    let mut counts = vec![0usize; 5];
    for a in &arr {
        counts[a.model] += 1;
    }
    assert!(arr.len() > 2_000, "need a large sample, got {}", arr.len());
    for m in 0..4 {
        assert!(
            counts[m] > counts[m + 1],
            "rank {m} ({}) must outdraw rank {} ({}): {counts:?}",
            counts[m],
            m + 1,
            counts[m + 1]
        );
    }
    // Empirical frequencies track the configured popularity within 15%.
    let pop = z.popularity();
    let total = arr.len() as f64;
    for m in 0..5 {
        let freq = counts[m] as f64 / total;
        assert!(
            (freq - pop[m]).abs() / pop[m] < 0.15,
            "model {m}: freq {freq} vs popularity {}",
            pop[m]
        );
    }
}

#[test]
fn markov_onoff_is_burstier_than_poisson() {
    let params = ScenarioParams {
        num_models: 1,
        duration: 2000.0,
        warmup: 0,
        ..ScenarioParams::new(1, 0x0FF0)
    };
    let w = MarkovOnOffWorkload::new(params);
    let arr = w.generate();
    assert!(arr.len() > 1_000, "need a large sample, got {}", arr.len());
    let gaps: Vec<f64> = arr.windows(2).map(|p| p[1].at - p[0].at).collect();
    let (_, cv) = mean_and_cv(&gaps);
    // On/off modulation makes inter-arrivals overdispersed vs Poisson.
    assert!(cv > 1.2, "on/off stream should have CV > 1.2, got {cv}");
    // Long-run rate ≈ rate_on × duty cycle.
    let realized = arr.len() as f64 / 2000.0;
    let expected = w.rate_on * w.duty_cycle();
    assert!(
        (realized - expected).abs() / expected < 0.15,
        "realized rate {realized} vs expected {expected}"
    );
}

#[test]
fn diurnal_peak_half_outdraws_trough_half() {
    let params = ScenarioParams {
        num_models: 2,
        duration: 400.0,
        warmup: 0,
        ..ScenarioParams::new(2, 0xD1A1)
    };
    let d = DiurnalWorkload::new(params);
    let arr = d.generate();
    let start = d.measure_start();
    // sin > 0 over the first half-period, < 0 over the second.
    let half = start + 200.0;
    let first = arr.iter().filter(|a| a.at < half).count();
    let second = arr.len() - first;
    assert!(
        first as f64 > second as f64 * 2.0,
        "peak half ({first}) must clearly outdraw trough half ({second})"
    );
    // Mean rate over the whole window stays near base_rate per model.
    let realized = arr.len() as f64 / (400.0 * 2.0);
    assert!(
        (realized - d.base_rate).abs() / d.base_rate < 0.15,
        "realized per-model rate {realized} vs base {}",
        d.base_rate
    );
}

#[test]
fn flash_crowd_spikes_the_target_model_only() {
    let params = ScenarioParams {
        num_models: 3,
        duration: 600.0,
        warmup: 0,
        ..ScenarioParams::new(3, 0xFC0D)
    };
    let f = FlashCrowdWorkload::new(params);
    let arr = f.generate();
    let (lo, hi) = f.spike_window();
    let rate_in = |model: usize, a: f64, b: f64| {
        arr.iter().filter(|x| x.model == model && x.at >= a && x.at < b).count() as f64 / (b - a)
    };
    // The spiking model runs near spike_factor × base inside the window...
    let spiked = rate_in(0, lo, hi);
    assert!(
        spiked > f.base_rate * f.spike_factor * 0.7,
        "spike rate {spiked} vs expected {}",
        f.base_rate * f.spike_factor
    );
    // ...and near base outside it.
    let before = rate_in(0, f.measure_start(), lo);
    assert!(
        before < f.base_rate * 1.5,
        "pre-spike rate {before} should sit near base {}",
        f.base_rate
    );
    // Other models never spike.
    for m in 1..3 {
        let r = rate_in(m, lo, hi);
        assert!(
            r < f.base_rate * 2.0,
            "model {m} rate {r} in spike window should stay near base"
        );
    }
}

#[test]
fn all_scenarios_deterministic_under_fixed_seed() {
    for &name in scenarios::names() {
        let params = ScenarioParams { duration: 12.0, ..ScenarioParams::new(3, 0xDE7E) };
        let a = scenarios::by_name(name, &params).unwrap().generate();
        let b = scenarios::by_name(name, &params).unwrap().generate();
        assert_eq!(a.len(), b.len(), "{name}: lengths differ across runs");
        assert!(
            a.iter().zip(&b).all(|(x, y)| x.at == y.at
                && x.model == y.model
                && x.input_len == y.input_len),
            "{name}: schedules differ across runs with the same seed"
        );

        let other = ScenarioParams { seed: 0xDE7E + 1, ..params };
        let c = scenarios::by_name(name, &other).unwrap().generate();
        assert!(
            a.len() != c.len() || a.iter().zip(&c).any(|(x, y)| x.at != y.at),
            "{name}: different seeds must produce different schedules"
        );
    }
}

#[test]
fn all_scenarios_respect_rate_scale() {
    // Doubling rate_scale should roughly double measured arrivals for
    // every registered scenario (warmup excluded).
    for &name in scenarios::names() {
        let base = ScenarioParams { duration: 600.0, ..ScenarioParams::new(3, 0x5CA1E) };
        let double = ScenarioParams { rate_scale: 2.0, ..base.clone() };
        let measured = |p: &ScenarioParams| {
            let gen = scenarios::by_name(name, p).unwrap();
            let start = gen.measure_start();
            gen.generate().iter().filter(|a| a.at >= start).count() as f64
        };
        let n1 = measured(&base);
        let n2 = measured(&double);
        let ratio = n2 / n1;
        assert!(
            (1.5..2.6).contains(&ratio),
            "{name}: rate_scale 2.0 gave ratio {ratio} ({n1} -> {n2})"
        );
    }
}

#[test]
fn scenario_streams_are_independent_per_model() {
    // Forked per-model streams must not be identical (a classic seeding
    // bug): model 0 and model 1 arrival times differ for every scenario
    // that generates per-model streams.
    let params = ScenarioParams { duration: 60.0, ..ScenarioParams::new(2, 7) };
    for &name in ["markov-onoff", "diurnal", "flash-crowd"].iter() {
        let gen = scenarios::by_name(name, &params).unwrap();
        let arr = gen.generate();
        let start = gen.measure_start();
        let m0: Vec<f64> =
            arr.iter().filter(|a| a.model == 0 && a.at >= start).map(|a| a.at).collect();
        let m1: Vec<f64> =
            arr.iter().filter(|a| a.model == 1 && a.at >= start).map(|a| a.at).collect();
        assert!(!m0.is_empty() && !m1.is_empty(), "{name}: empty per-model stream");
        assert!(
            m0.len() != m1.len() || m0.iter().zip(&m1).any(|(a, b)| a != b),
            "{name}: model streams are clones"
        );
    }
}

#[test]
fn rng_sanity_for_sampler_reuse() {
    // The scenario generators lean on exponential(); spot-check its mean
    // here so a sampler regression fails close to the source.
    let mut rng = Rng::seeded(99);
    let n = 100_000;
    let mean = (0..n).map(|_| rng.exponential(8.0)).sum::<f64>() / n as f64;
    assert!((mean - 0.125).abs() < 0.005, "exponential mean {mean}");
}
