//! Cluster-layer equivalence pins (DESIGN.md §8):
//!
//! 1. **Single-group equivalence** — a `G = 1` `PlacementSpec` (any
//!    router) reproduces the legacy no-placement `SimSystem` runs
//!    bit-for-bit: same `RequestRecord`s, `SwapRecord`s, `DropRecord`s,
//!    event counts, memory marks, and link traffic, across the full
//!    scenario registry, for both the `Async` and `ChunkedPipelined`
//!    load designs, open and closed loop.
//! 2. **Group accounting** — multi-group runs conserve everything: per
//!    group tags partition the flat records, per-group aggregates match
//!    the tagged records, and completions + drops cover every arrival.

use computron::config::{
    LoadDesign, PlacementSpec, RouterKind, SchedulerKind, SystemConfig,
};
use computron::coordinator::router;
use computron::sim::{Driver, SimReport, SimSystem};
use computron::workload::scenarios;

fn base_cfg(design: LoadDesign) -> SystemConfig {
    let mut cfg = SystemConfig::workload_experiment(3, 2, 8);
    cfg.engine.load_design = design;
    cfg
}

fn run_scenario(cfg: SystemConfig, name: &str, duration: f64) -> SimReport {
    let mut cfg = cfg;
    cfg.scenario = Some(name.to_string());
    let (sys, _) = SimSystem::from_scenario(cfg, duration, 0xC1_0572).unwrap();
    sys.run()
}

fn assert_bit_identical(tag: &str, a: &SimReport, b: &SimReport) {
    assert_eq!(a.requests, b.requests, "{tag}: request records diverged");
    assert_eq!(a.swaps, b.swaps, "{tag}: swap records diverged");
    assert_eq!(a.drops, b.drops, "{tag}: drop records diverged");
    assert_eq!(a.events, b.events, "{tag}: event counts diverged");
    assert_eq!(a.mem_high_water, b.mem_high_water, "{tag}: memory diverged");
    assert_eq!(a.h2d_bytes, b.h2d_bytes, "{tag}: H2D traffic diverged");
    assert_eq!(a.d2h_bytes, b.d2h_bytes, "{tag}: D2H traffic diverged");
    assert_eq!(a.swap_stats, b.swap_stats, "{tag}: swap stats diverged");
    assert_eq!(a.sim_end, b.sim_end, "{tag}: end times diverged");
}

#[test]
fn g1_placement_reproduces_legacy_open_loop_bit_for_bit() {
    // The acceptance anchor: an explicit single-group placement — under
    // EVERY router, since one group leaves nothing to route — must be
    // indistinguishable from the legacy no-placement system on every
    // scenario, for both load designs.
    for design in [LoadDesign::AsyncPipelined, LoadDesign::ChunkedPipelined] {
        for &name in scenarios::names() {
            let legacy = run_scenario(base_cfg(design), name, 6.0);
            for &kind in router::KINDS.iter() {
                let mut cfg = base_cfg(design);
                cfg.placement =
                    Some(PlacementSpec::replicated(1, cfg.parallel, 3, kind));
                let explicit = run_scenario(cfg, name, 6.0);
                let tag = format!("{name}/{}/{}", design.name(), kind.name());
                assert_bit_identical(&tag, &legacy, &explicit);
            }
        }
    }
}

#[test]
fn g1_placement_reproduces_legacy_closed_loop_bit_for_bit() {
    // §5.1 alternating-blocking worst case across grid shapes.
    for (tp, pp) in [(1usize, 1usize), (2, 2), (1, 4)] {
        for design in [LoadDesign::AsyncPipelined, LoadDesign::ChunkedPipelined] {
            let run = |placed: bool| {
                let mut cfg = SystemConfig::swap_experiment(tp, pp);
                cfg.engine.load_design = design;
                if placed {
                    cfg.placement = Some(PlacementSpec::replicated(
                        1,
                        cfg.parallel,
                        2,
                        RouterKind::ResidentAffinity,
                    ));
                }
                let mut sys = SimSystem::new(cfg, Driver::AlternatingBlocking {
                    models: 2,
                    input_len: 2,
                    total: 8,
                })
                .unwrap();
                sys.preload(&[1]);
                sys.run()
            };
            let tag = format!("tp{tp}pp{pp}/{}", design.name());
            assert_bit_identical(&tag, &run(false), &run(true));
        }
    }
}

#[test]
fn g1_placement_reproduces_legacy_with_slos_and_shed() {
    // Admission control must survive the placement path too: drops and
    // deadlines identical.
    for &name in scenarios::names() {
        let mk = |placed: bool| {
            let mut cfg = SystemConfig::workload_experiment(3, 1, 4);
            cfg.engine.scheduler = SchedulerKind::Shed;
            cfg.set_slos(&[0.6, 0.6, 0.6]).unwrap();
            if placed {
                cfg.placement =
                    Some(PlacementSpec::replicated(1, cfg.parallel, 3, RouterKind::LeastLoaded));
            }
            cfg
        };
        let legacy = run_scenario(mk(false), name, 6.0);
        let explicit = run_scenario(mk(true), name, 6.0);
        assert_bit_identical(&format!("{name}/shed"), &legacy, &explicit);
        assert!(
            legacy.requests.len() + legacy.drops.len() > 0,
            "{name}: scenario generated no traffic"
        );
    }
}

#[test]
fn multi_group_runs_conserve_all_accounting() {
    // G = 2 and G = 3 replicated placements under every router, across
    // the registry: engine invariants hold, group tags partition the
    // records, and the per-group aggregates match the tagged records.
    for &g in &[2usize, 3] {
        for &kind in router::KINDS.iter() {
            for &name in scenarios::names() {
                let mut cfg = SystemConfig::workload_experiment(3, 2, 8);
                cfg.placement = Some(PlacementSpec::replicated(g, cfg.parallel, 3, kind));
                let report = run_scenario(cfg, name, 5.0);
                let tag = format!("{name}/G={g}/{}", kind.name());
                assert_eq!(report.violations, 0, "{tag}");
                assert_eq!(report.oom_events, 0, "{tag}");
                assert!(report.drops.is_empty(), "{tag}: fcfs never drops");
                assert_eq!(report.groups.len(), g, "{tag}");
                let s = report.swap_stats;
                assert_eq!(s.loads_started, s.loads_completed + s.loads_cancelled, "{tag}");
                assert_eq!(s.offloads_started, s.offloads_completed, "{tag}");
                let mut tagged_requests = 0;
                let mut tagged_swaps = 0;
                for gs in &report.groups {
                    let reqs =
                        report.requests.iter().filter(|r| r.group == gs.group).count();
                    assert_eq!(reqs, gs.requests, "{tag}: group {} requests", gs.group);
                    let swaps = report
                        .swaps
                        .iter()
                        .filter(|sw| sw.group == gs.group && !sw.cancelled)
                        .count();
                    assert_eq!(swaps, gs.swaps, "{tag}: group {} swaps", gs.group);
                    let bytes: u64 = report
                        .swaps
                        .iter()
                        .filter(|sw| sw.group == gs.group && !sw.cancelled)
                        .map(|sw| sw.bytes as u64)
                        .sum();
                    assert_eq!(bytes, gs.swap_bytes, "{tag}: group {} swap bytes", gs.group);
                    tagged_requests += reqs;
                    tagged_swaps += swaps;
                    // Worker-series lengths match the group's grid.
                    assert_eq!(gs.h2d_bytes.len(), gs.tp * gs.pp, "{tag}");
                }
                assert_eq!(tagged_requests, report.requests.len(), "{tag}: tags partition");
                assert_eq!(
                    tagged_swaps,
                    report.swaps.iter().filter(|sw| !sw.cancelled).count(),
                    "{tag}"
                );
                assert_eq!(
                    report.groups.iter().map(|gs| gs.events).sum::<u64>(),
                    report.events,
                    "{tag}: per-group events sum to the cluster total"
                );
                // Flat per-GPU series concatenate the groups' series.
                assert_eq!(
                    report.h2d_bytes.len(),
                    report.groups.iter().map(|gs| gs.h2d_bytes.len()).sum::<usize>(),
                    "{tag}"
                );
                // Every model got served (replication never strands one).
                for m in 0..3 {
                    assert!(
                        report.requests.iter().any(|r| r.model == m),
                        "{tag}: model {m} starved"
                    );
                }
            }
        }
    }
}

#[test]
fn heterogeneous_grids_per_group() {
    // A placement may give each group its own grid: model 2 on a private
    // TP=1 PP=1 group with less memory, models 0/1 on the shared 2x2
    // grid. Everything still drains and the per-group worker series
    // reflect the per-group world sizes.
    let mut cfg = SystemConfig::workload_experiment(3, 2, 8);
    cfg.placement = Some(PlacementSpec {
        router: RouterKind::LeastLoaded,
        groups: vec![
            computron::config::GroupSpec::new(cfg.parallel, vec![0, 1]),
            computron::config::GroupSpec {
                parallel: computron::config::ParallelConfig::new(1, 1),
                models: vec![2],
                gpu_mem: Some(30_000_000_000),
                link_bandwidth: Some(16.0e9),
            },
        ],
    });
    let report = run_scenario(cfg, "uniform", 5.0);
    assert_eq!(report.violations, 0);
    assert_eq!(report.oom_events, 0);
    assert_eq!(report.groups.len(), 2);
    assert_eq!(report.groups[0].h2d_bytes.len(), 4, "2x2 grid");
    assert_eq!(report.groups[1].h2d_bytes.len(), 1, "1x1 grid");
    assert_eq!(report.h2d_bytes.len(), 5, "flat series concatenates 4 + 1");
    // Model 2's single host serves all of its traffic.
    assert!(report.requests.iter().filter(|r| r.model == 2).all(|r| r.group == 1));
    assert!(report.requests.iter().filter(|r| r.model < 2).all(|r| r.group == 0));
}
