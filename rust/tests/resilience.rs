//! Resilience regression suite (DESIGN.md §11): fault injection, retry /
//! re-homing, health-aware routing, and the elastic autoscaler exercised
//! through the public API. The heavyweight goodput-dip / recovery-time
//! oracles run in `benches/resilience_suite.rs`; this file pins the
//! invariants those oracles stand on:
//!
//! - event conservation: per-group events + dead-event drops +
//!   cluster-scoped events account for every event the queue processed;
//! - replicated fleets with a retry budget lose nothing across an outage;
//! - fail-fast (zero-retry) fleets lose exactly the harvested requests,
//!   each recorded with `DropReason::Fault`;
//! - health-aware routing steers every post-failure arrival away from a
//!   dead group;
//! - every chaos schedule in the registry validates, runs to completion,
//!   and is a pure function of (config, seed).

use computron::cluster::fault::{
    chaos_by_name, chaos_names, AutoscalePolicy, ChaosParams, FaultEvent, FaultKind, FaultPlan,
    RetryPolicy,
};
use computron::config::{PlacementSpec, RouterKind, SystemConfig};
use computron::coordinator::DropReason;
use computron::sim::{Arrival, Driver, SimCluster, SimReport};

const SEED: u64 = 0x5E51_11E7;

fn replicated_cfg(g: usize, router: RouterKind) -> SystemConfig {
    let mut cfg = SystemConfig::workload_experiment(3, 2, 8);
    cfg.placement = Some(PlacementSpec::replicated(g, cfg.parallel, 3, router));
    cfg
}

fn steady_arrivals(n: usize, spacing: f64) -> Vec<Arrival> {
    (0..n)
        .map(|i| Arrival { at: spacing * i as f64, model: i % 3, input_len: 8 })
        .collect()
}

/// Per-group events + dead-event drops + cluster-scoped events must
/// cover every event the queue processed — nothing is double-counted or
/// silently discarded (DESIGN.md §11).
fn conservation_holds(report: &SimReport) -> bool {
    report.groups.iter().map(|g| g.events).sum::<u64>()
        + report.fault_stats.dead_event_drops
        + report.fault_stats.cluster_events
        == report.events
}

#[test]
fn replicated_outage_with_retries_loses_nothing() {
    let mut cfg = replicated_cfg(2, RouterKind::LeastLoaded);
    cfg.faults = Some(FaultPlan {
        events: vec![
            FaultEvent { at: 2.0, kind: FaultKind::GroupFail { group: 1 } },
            FaultEvent { at: 5.0, kind: FaultKind::GroupRecover { group: 1 } },
        ],
        retry: RetryPolicy { max_retries: 3, backoff: 0.05 },
        autoscale: None,
    });
    let arrivals = steady_arrivals(32, 0.25);
    let mut sys = SimCluster::new(cfg, Driver::Open(arrivals)).unwrap();
    sys.preload_warm();
    let report = sys.run();
    assert_eq!(report.fault_stats.lost, 0, "surviving replica + retries absorb the outage");
    assert_eq!(report.requests.len(), 32, "every arrival completes");
    assert!(report.drops.is_empty(), "nothing dropped");
    assert_eq!(report.groups[1].failures, 1);
    assert!((report.groups[1].downtime - 3.0).abs() < 1e-9, "downtime = fail→recover gap");
    assert_eq!(report.groups[1].downtime, report.groups[1].recovery_time);
    assert!(conservation_holds(&report));
}

#[test]
fn fail_fast_loses_exactly_the_harvested_requests() {
    let mut cfg = replicated_cfg(1, RouterKind::RoundRobin);
    cfg.faults = Some(FaultPlan {
        events: vec![FaultEvent { at: 1.0, kind: FaultKind::GroupFail { group: 0 } }],
        retry: RetryPolicy { max_retries: 0, backoff: 0.05 },
        autoscale: None,
    });
    let arrivals = steady_arrivals(12, 0.3);
    let mut sys = SimCluster::new(cfg, Driver::Open(arrivals)).unwrap();
    sys.preload_warm();
    let report = sys.run();
    assert!(report.fault_stats.lost > 0, "no retries + no recovery must lose requests");
    assert_eq!(report.requests.len() + report.drops.len(), 12, "arrival accounting");
    assert!(
        report.drops.iter().all(|d| d.reason == DropReason::Fault),
        "fault drops carry the fault reason"
    );
    assert_eq!(report.drops.len() as u64, report.fault_stats.lost);
    assert_eq!(report.groups[0].lost, report.fault_stats.lost);
    assert!(report.groups[0].downtime > 0.0, "open outage runs to sim end");
    assert_eq!(report.groups[0].recovery_time, 0.0, "no completed recovery");
    assert!(conservation_holds(&report));
}

#[test]
fn health_aware_routing_steers_around_a_dead_group() {
    // Group 1 dies before any arrival and never recovers: a round-robin
    // router with health masking must send *every* request to group 0,
    // with no retries needed.
    let mut cfg = replicated_cfg(2, RouterKind::RoundRobin);
    cfg.faults = Some(FaultPlan {
        events: vec![FaultEvent { at: 0.0, kind: FaultKind::GroupFail { group: 1 } }],
        retry: RetryPolicy { max_retries: 1, backoff: 0.05 },
        autoscale: None,
    });
    let arrivals = steady_arrivals(20, 0.3);
    let mut sys = SimCluster::new(cfg, Driver::Open(arrivals)).unwrap();
    sys.preload_warm();
    let report = sys.run();
    assert_eq!(report.requests.len(), 20);
    assert_eq!(report.fault_stats.lost, 0);
    assert!(
        report.requests.iter().all(|r| r.group == 0),
        "every request must route to the surviving group"
    );
    assert_eq!(report.groups[1].requests, 0);
    assert!(conservation_holds(&report));
}

#[test]
fn preemption_warning_rehomes_without_loss() {
    let mut cfg = replicated_cfg(2, RouterKind::LeastLoaded);
    cfg.faults = Some(FaultPlan {
        events: vec![FaultEvent {
            at: 1.5,
            kind: FaultKind::GroupPreempt { group: 1, warning: 0.8 },
        }],
        retry: RetryPolicy { max_retries: 2, backoff: 0.05 },
        autoscale: None,
    });
    let arrivals = steady_arrivals(24, 0.3);
    let mut sys = SimCluster::new(cfg, Driver::Open(arrivals)).unwrap();
    sys.preload_warm();
    let report = sys.run();
    assert_eq!(report.fault_stats.lost, 0, "warned preemption + replica loses nothing");
    assert_eq!(report.requests.len(), 24);
    // Drain fires at 1.5, fail at 2.3 — both injected actions.
    assert_eq!(report.fault_stats.injected, 2);
    assert!(
        report.requests.iter().all(|r| r.group == 0 || r.arrival < 1.5),
        "arrivals during/after the warning avoid the draining group"
    );
    assert!(conservation_holds(&report));
}

#[test]
fn autoscaler_under_burst_keeps_fleet_serving_and_terminates() {
    // Aggressive thresholds + heavy burst: the controller keeps both
    // groups serving the burst, and the run must still terminate (the
    // tick re-arms only while the queue is non-empty — the regression
    // that would otherwise keep an empty sim alive forever).
    let mut cfg = replicated_cfg(2, RouterKind::LeastLoaded);
    cfg.faults = Some(FaultPlan {
        events: Vec::new(),
        retry: RetryPolicy::default(),
        autoscale: Some(AutoscalePolicy {
            interval: 0.25,
            high_queue: 2.0,
            low_queue: 0.5,
            min_active: 1,
        }),
    });
    let arrivals = steady_arrivals(60, 0.05);
    let mut sys = SimCluster::new(cfg, Driver::Open(arrivals)).unwrap();
    sys.preload_warm();
    let report = sys.run();
    assert_eq!(report.requests.len() + report.drops.len(), 60);
    assert!(report.fault_stats.cluster_events > 0, "autoscale ticks are cluster events");
    assert!(
        report.groups.iter().all(|g| g.requests > 0),
        "burst load must spread across joined groups: {:?}",
        report.groups.iter().map(|g| g.requests).collect::<Vec<_>>()
    );
    assert!(conservation_holds(&report));
}

/// Every chaos schedule in the registry produces a plan that validates
/// against its placement, runs to completion with full arrival + event
/// accounting, and replays bit-for-bit from the same seed.
#[test]
fn chaos_registry_runs_deterministically_across_group_counts() {
    let duration = 6.0;
    for name in chaos_names() {
        for g in [1usize, 2, 4] {
            let params = ChaosParams { seed: SEED, duration, num_groups: g };
            let plan = chaos_by_name(name, &params)
                .unwrap_or_else(|| panic!("chaos schedule {name} missing from registry"));
            plan.validate(g).unwrap_or_else(|e| panic!("{name}/G={g}: invalid plan: {e}"));

            let run = || {
                let mut cfg = replicated_cfg(g, RouterKind::LeastLoaded);
                cfg.faults = Some(plan.clone());
                let arrivals = steady_arrivals(30, duration / 30.0);
                let total = arrivals.len();
                let mut sys = SimCluster::new(cfg, Driver::Open(arrivals)).unwrap();
                sys.preload_warm();
                let report = sys.run();
                let tag = format!("{name}/G={g}");
                assert_eq!(
                    report.requests.len() + report.drops.len(),
                    total,
                    "{tag}: completions + drops must cover every arrival"
                );
                assert!(conservation_holds(&report), "{tag}: event conservation");
                report
            };
            let a = run();
            let b = run();
            assert_eq!(a.requests, b.requests, "{name}/G={g}: replay differs");
            assert_eq!(a.drops, b.drops, "{name}/G={g}: replay drops differ");
            assert_eq!(a.fault_stats, b.fault_stats, "{name}/G={g}: fault stats differ");
            assert_eq!(a.events, b.events, "{name}/G={g}: event counts differ");
        }
    }
}

/// The chaos generators themselves are pure functions of their params —
/// same seed ⇒ same plan, different seed ⇒ (for these schedules) a
/// different one.
#[test]
fn chaos_generators_are_seeded() {
    let p = ChaosParams { seed: 7, duration: 60.0, num_groups: 4 };
    for name in chaos_names() {
        let a = chaos_by_name(name, &p).unwrap();
        let b = chaos_by_name(name, &p).unwrap();
        assert_eq!(a, b, "{name}: same params must reproduce the plan");
    }
    // The structural schedules always inject (gpu-mtbf's exponential
    // draws may legitimately skip a short window).
    for name in ["rack-correlated", "spot-wave"] {
        let plan = chaos_by_name(name, &p).unwrap();
        assert!(!plan.events.is_empty(), "{name}: a 60 s schedule must inject something");
        assert!(plan.events.iter().all(|e| e.kind.group() < 4), "{name}: groups in range");
    }
}
