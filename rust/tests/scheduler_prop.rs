//! Property tests for the scheduling & admission-control registry
//! (`coordinator::scheduler`, DESIGN.md §5):
//!
//! 1. `fcfs` ranks candidates exactly like the pre-registry engine's
//!    oldest-head sort, and — replayed decision-for-decision against a
//!    mirror of the legacy discipline — never batches a model while
//!    another schedulable model holds a strictly older head.
//! 2. `edf` never inverts two feasible deadlines: its order is
//!    non-decreasing in deadline.
//! 3. `shed` only drops requests that are provably deadline-infeasible
//!    under its lower-bound cost model.
//! 4. With no SLOs configured, `fcfs` and `edf` produce bit-identical
//!    seeded `SimReport`s across the whole scenario registry.

use computron::config::{EngineConfig, SchedulerKind, SystemConfig};
use computron::coordinator::engine::Engine;
use computron::coordinator::entry::{Entry, EntryId, LoadDirection, ModelId};
use computron::coordinator::scheduler::{self, Candidate, ModelCost, SchedCtx, Scheduler};
use computron::coordinator::swap::Residency;
use computron::sim::SimSystem;
use computron::util::prop;
use computron::util::rng::Rng;
use computron::workload::scenarios;

fn random_candidates(rng: &mut Rng) -> Vec<Candidate> {
    let n = prop::usize_in(rng, 1, 8);
    (0..n)
        .map(|model| Candidate {
            model,
            head_arrival: (rng.index(50) as f64) * 0.25,
            head_deadline: if rng.index(4) == 0 {
                f64::INFINITY
            } else {
                (rng.index(80) as f64) * 0.25
            },
            queue_len: prop::usize_in(rng, 1, 12),
            residency: match rng.index(4) {
                0 => Residency::Offloaded,
                1 => Residency::Loading,
                2 => Residency::Resident,
                _ => Residency::Offloading,
            },
            inflight: rng.index(3),
            // Per-model cost constants (heterogeneous in general).
            cost: ModelCost {
                swap_cost: (rng.index(20) as f64) * 0.1,
                swap_floor: (rng.index(10) as f64) * 0.1,
                bytes: rng.index(1 << 30),
                chunked: false,
            },
            weight: [0.5, 1.0, 2.0][rng.index(3)],
        })
        .collect()
}

fn ctx(rng: &mut Rng) -> SchedCtx {
    SchedCtx {
        now: (rng.index(100) as f64) * 0.25,
        max_batch_size: prop::usize_in(rng, 1, 8),
        exec_floor: (rng.index(5) as f64) * 0.01,
    }
}

#[test]
fn fcfs_order_matches_legacy_oldest_head_sort() {
    prop::check(
        "fcfs-legacy-sort",
        |rng: &mut Rng| (ctx(rng), random_candidates(rng)),
        |(ctx, cands)| {
            // The pre-registry engine's exact sort key.
            let mut legacy: Vec<(f64, ModelId)> =
                cands.iter().map(|c| (c.head_arrival, c.model)).collect();
            legacy.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));

            let mut ours = cands.clone();
            scheduler::by_name("fcfs").unwrap().order(ctx, &mut ours);
            let got: Vec<(f64, ModelId)> =
                ours.iter().map(|c| (c.head_arrival, c.model)).collect();
            if got != legacy {
                return Err(format!("fcfs diverged: {got:?} vs legacy {legacy:?}"));
            }
            Ok(())
        },
    );
}

#[test]
fn edf_never_inverts_two_feasible_deadlines() {
    prop::check(
        "edf-no-deadline-inversion",
        |rng: &mut Rng| (ctx(rng), random_candidates(rng)),
        |(ctx, cands)| {
            let mut ours = cands.clone();
            scheduler::by_name("edf").unwrap().order(ctx, &mut ours);
            for pair in ours.windows(2) {
                if pair[0].head_deadline > pair[1].head_deadline {
                    return Err(format!(
                        "deadline inversion: model {} (deadline {}) before model {} ({})",
                        pair[0].model,
                        pair[0].head_deadline,
                        pair[1].model,
                        pair[1].head_deadline
                    ));
                }
            }
            Ok(())
        },
    );
}

/// Mirror of the engine state the legacy FCFS discipline keys on,
/// reconstructed purely from the engine's observable outputs.
struct FcfsMirror {
    /// Queued arrival times per model, oldest first.
    queues: Vec<Vec<f64>>,
    residency: Vec<Residency>,
    inflight: Vec<usize>,
    /// Remaining worker acks per in-flight load entry.
    load_acks: std::collections::HashMap<EntryId, (ModelId, LoadDirection, usize)>,
}

impl FcfsMirror {
    fn new(models: usize) -> FcfsMirror {
        FcfsMirror {
            queues: vec![Vec::new(); models],
            residency: vec![Residency::Offloaded; models],
            inflight: vec![0; models],
            load_acks: std::collections::HashMap::new(),
        }
    }

    /// Replay one drained entry, checking the legacy-discipline batch
    /// invariant: a batch for `m` is only legal while no OTHER
    /// schedulable model (resident, below the in-flight limit, nonempty
    /// queue) holds a strictly older head (ties break by model id).
    fn replay(
        &mut self,
        entry: &Entry,
        world: usize,
        max_inflight: usize,
        max_batch: usize,
    ) -> Result<(), String> {
        match entry {
            Entry::Batch(b) => {
                let m = b.model;
                if b.batch_size() > max_batch {
                    return Err("batch exceeds max batch size".into());
                }
                if b.batch_size() > self.queues[m].len() {
                    return Err("batch larger than queued work".into());
                }
                let head = self.queues[m][0];
                // The batch must pack the oldest queued requests, in order.
                for (i, req) in b.requests.iter().enumerate() {
                    if req.arrival != self.queues[m][i] {
                        return Err(format!(
                            "batch for model {m} skipped the queue front: \
                             got arrival {}, expected {}",
                            req.arrival, self.queues[m][i]
                        ));
                    }
                }
                for other in 0..self.queues.len() {
                    if other == m || self.queues[other].is_empty() {
                        continue;
                    }
                    let oh = self.queues[other][0];
                    let older = oh < head || (oh == head && other < m);
                    if !older {
                        continue;
                    }
                    match self.residency[other] {
                        // A schedulable resident model with an older head
                        // must have been batched first.
                        Residency::Resident if self.inflight[other] < max_inflight => {
                            return Err(format!(
                                "fcfs batched model {m} (head {head}) while schedulable \
                                 model {other} held an older head ({oh})"
                            ));
                        }
                        // An offloaded model with an older head either
                        // started its swap earlier in this pump (mirror
                        // would show Loading) or was Blocked — and a
                        // blocked older head stalls every younger queue.
                        Residency::Offloaded => {
                            return Err(format!(
                                "fcfs batched model {m} (head {head}) past offloaded \
                                 model {other} with an older head ({oh})"
                            ));
                        }
                        // At the in-flight limit, Loading, or Offloading:
                        // legally bypassed without stalling.
                        _ => {}
                    }
                }
                self.queues[m].drain(..b.batch_size());
                self.inflight[m] += 1;
            }
            Entry::Load(l) => {
                self.residency[l.model] = match l.dir {
                    LoadDirection::Load => Residency::Loading,
                    LoadDirection::Offload => Residency::Offloading,
                    LoadDirection::Cancel => {
                        unreachable!("fcfs over the async design never cancels")
                    }
                };
                self.load_acks.insert(l.id, (l.model, l.dir, world));
            }
        }
        Ok(())
    }

    fn ack_load(&mut self, id: EntryId) {
        let (model, dir, remaining) = *self.load_acks.get(&id).expect("unknown load");
        if remaining == 1 {
            self.load_acks.remove(&id);
            self.residency[model] = match dir {
                LoadDirection::Load => Residency::Resident,
                LoadDirection::Offload => Residency::Offloaded,
                LoadDirection::Cancel => unreachable!("mirror never records cancels"),
            };
        } else {
            self.load_acks.insert(id, (model, dir, remaining - 1));
        }
    }
}

#[test]
fn fcfs_matches_legacy_engine_decision_for_decision() {
    prop::check(
        "fcfs-decision-replay",
        |rng: &mut Rng| {
            let models = prop::usize_in(rng, 2, 4);
            let cap = prop::usize_in(rng, 1, models);
            let reqs: Vec<usize> = (0..48).map(|_| rng.index(models)).collect();
            (models, cap, reqs)
        },
        |(models, cap, reqs)| {
            let world = 2;
            let max_batch = 4;
            let cfg = EngineConfig {
                max_batch_size: max_batch,
                resident_cap: *cap,
                ..EngineConfig::default()
            };
            let mut e = Engine::new(*models, world, 1, cfg, 7);
            let mut mirror = FcfsMirror::new(*models);
            let mut pending_loads: Vec<EntryId> = Vec::new();
            let mut pending_batches: Vec<(EntryId, ModelId)> = Vec::new();
            let mut now = 0.0;
            let drain = |e: &mut Engine,
                             mirror: &mut FcfsMirror,
                             loads: &mut Vec<EntryId>,
                             batches: &mut Vec<(EntryId, ModelId)>|
             -> Result<(), String> {
                for entry in e.drain_outbox() {
                    mirror.replay(&entry, world, 1, max_batch)?;
                    match entry {
                        Entry::Batch(b) => batches.push((b.id, b.model)),
                        Entry::Load(l) => loads.push(l.id),
                    }
                }
                Ok(())
            };
            for &m in reqs {
                now += 0.125;
                e.on_request(now, m, 8);
                mirror.queues[m].push(now);
                drain(&mut e, &mut mirror, &mut pending_loads, &mut pending_batches)?;
                // Randomly (deterministically from `now`) complete work.
                if !pending_loads.is_empty() && (now * 8.0) as u64 % 2 == 0 {
                    let id = pending_loads.remove(0);
                    now += 0.5;
                    for _ in 0..world {
                        e.on_load_ack(now, id);
                        mirror.ack_load(id);
                    }
                    drain(&mut e, &mut mirror, &mut pending_loads, &mut pending_batches)?;
                }
                if pending_batches.len() > 2 {
                    let (id, bm) = pending_batches.remove(0);
                    now += 0.25;
                    e.on_batch_done(now, id);
                    mirror.inflight[bm] -= 1;
                    drain(&mut e, &mut mirror, &mut pending_loads, &mut pending_batches)?;
                }
            }
            Ok(())
        },
    );
}

#[test]
fn shed_drops_only_provably_infeasible_requests() {
    prop::check(
        "shed-provable-drops",
        |rng: &mut Rng| {
            let models = prop::usize_in(rng, 2, 4);
            let cap = prop::usize_in(rng, 1, models);
            // A mix of tight and loose SLOs.
            let slos: Vec<f64> =
                (0..models).map(|_| [0.25, 0.5, 2.0, 16.0][rng.index(4)]).collect();
            let swap_floor = (rng.index(8) as f64) * 0.1;
            let exec_floor = (rng.index(4) as f64) * 0.05;
            let reqs: Vec<usize> = (0..48).map(|_| rng.index(models)).collect();
            (models, cap, slos, swap_floor, exec_floor, reqs)
        },
        |(models, cap, slos, swap_floor, exec_floor, reqs)| {
            let cfg = EngineConfig {
                max_batch_size: 4,
                resident_cap: *cap,
                scheduler: SchedulerKind::Shed,
                ..EngineConfig::default()
            };
            let mut e = Engine::new(*models, 1, 1, cfg, 7);
            e.set_slos(slos);
            e.set_uniform_cost_model(*swap_floor, *swap_floor, *exec_floor);
            let mut pending_loads: Vec<EntryId> = Vec::new();
            let mut pending_batches: Vec<EntryId> = Vec::new();
            let mut now = 0.0;
            for &m in reqs {
                now += 0.125;
                e.on_request(now, m, 8);
                for entry in e.drain_outbox() {
                    match entry {
                        Entry::Batch(b) => pending_batches.push(b.id),
                        Entry::Load(l) => pending_loads.push(l.id),
                    }
                }
                if !pending_loads.is_empty() && (now * 8.0) as u64 % 2 == 0 {
                    let id = pending_loads.remove(0);
                    now += 0.5;
                    e.on_load_ack(now, id);
                    for entry in e.drain_outbox() {
                        match entry {
                            Entry::Batch(b) => pending_batches.push(b.id),
                            Entry::Load(l) => pending_loads.push(l.id),
                        }
                    }
                }
                if pending_batches.len() > 1 {
                    let id = pending_batches.remove(0);
                    now += 0.25;
                    e.on_batch_done(now, id);
                    for entry in e.drain_outbox() {
                        match entry {
                            Entry::Batch(b) => pending_batches.push(b.id),
                            Entry::Load(l) => pending_loads.push(l.id),
                        }
                    }
                }
            }
            // Every drop must be provably infeasible at its drop time
            // under the engine's lower-bound cost model.
            for d in e.take_dropped() {
                let cold = match d.residency {
                    Residency::Offloaded | Residency::Offloading => *swap_floor,
                    _ => 0.0,
                };
                let earliest = d.dropped_at + *exec_floor + cold;
                if earliest <= d.deadline {
                    return Err(format!(
                        "dropped request {} was still feasible: earliest completion \
                         {earliest} <= deadline {} (residency {:?})",
                        d.id, d.deadline, d.residency
                    ));
                }
                if d.dropped_at < d.arrival {
                    return Err("drop predates arrival".into());
                }
            }
            Ok(())
        },
    );
}

#[test]
fn fcfs_and_edf_reports_identical_without_slos_across_registry() {
    // With no SLOs every deadline is infinite, so EDF's (deadline,
    // arrival, model) key collapses to FCFS's (arrival, model): the two
    // disciplines must produce bit-identical seeded runs on every
    // scenario in the registry.
    for &name in scenarios::names() {
        let run = |kind: SchedulerKind| {
            let mut cfg = SystemConfig::workload_experiment(3, 2, 8);
            cfg.scenario = Some(name.to_string());
            cfg.engine.scheduler = kind;
            let (sys, _) = SimSystem::from_scenario(cfg, 8.0, 0xD15C).unwrap();
            sys.run()
        };
        let fcfs = run(SchedulerKind::Fcfs);
        let edf = run(SchedulerKind::Edf);
        assert_eq!(fcfs.requests, edf.requests, "{name}: request records diverged");
        assert_eq!(fcfs.swaps, edf.swaps, "{name}: swap records diverged");
        assert_eq!(fcfs.events, edf.events, "{name}: event counts diverged");
        assert!(fcfs.drops.is_empty() && edf.drops.is_empty());
    }
}
