//! Property tests for every `ReplacementPolicy` (LRU / LFU / FIFO /
//! Random) under randomized access traces, against straightforward
//! reference models:
//!
//! - the victim is always drawn from the candidate set (never an
//!   arbitrary model),
//! - evicted models are forgotten: re-insertion restarts their history
//!   (FIFO position, LRU recency) rather than resuming the old one,
//! - LRU picks the genuinely least-recently-used candidate,
//! - LFU picks the least-frequently-accessed candidate,
//! - FIFO picks the earliest-inserted resident candidate,
//! - Random is deterministic per seed and covers the candidate set.

use computron::config::PolicyKind;
use computron::coordinator::policy::{make_policy, Fifo, Lru, RandomPolicy, ReplacementPolicy};
use computron::util::prop;
use computron::util::rng::Rng;

const ALL_KINDS: [PolicyKind; 4] =
    [PolicyKind::Lru, PolicyKind::Lfu, PolicyKind::Fifo, PolicyKind::Random];

/// One randomized trace event.
#[derive(Clone, Debug)]
enum Op {
    Insert(usize),
    Access(usize),
    Evict(usize),
}

/// Generate a random but *well-formed* trace: models are inserted before
/// they are accessed/evicted, mirroring how the engine drives a policy
/// (insert on load-complete, access on batch submit, evict on offload).
fn gen_trace(rng: &mut Rng, num_models: usize, len: usize) -> Vec<Op> {
    let mut resident: Vec<usize> = Vec::new();
    let mut ops = Vec::new();
    for _ in 0..len {
        let roll = rng.f64();
        if resident.is_empty() || roll < 0.35 {
            let m = rng.index(num_models);
            if !resident.contains(&m) {
                resident.push(m);
                ops.push(Op::Insert(m));
            }
        } else if roll < 0.8 {
            let m = resident[rng.index(resident.len())];
            ops.push(Op::Access(m));
        } else {
            let i = rng.index(resident.len());
            let m = resident.remove(i);
            ops.push(Op::Evict(m));
        }
    }
    ops
}

/// Replay a trace into a policy, timestamping ops 1.0 apart, and return
/// the reference state: (resident set, last-access time, access count,
/// insertion sequence) per model.
struct Reference {
    resident: Vec<usize>,
    last_access: Vec<f64>,
    counts: Vec<u64>,
    inserted_seq: Vec<u64>,
}

fn replay(policy: &mut dyn ReplacementPolicy, ops: &[Op], num_models: usize) -> Reference {
    let mut r = Reference {
        resident: Vec::new(),
        last_access: vec![f64::NEG_INFINITY; num_models],
        counts: vec![0; num_models],
        inserted_seq: vec![u64::MAX; num_models],
    };
    let mut now = 0.0;
    let mut seq = 0;
    for op in ops {
        now += 1.0;
        match *op {
            Op::Insert(m) => {
                policy.on_insert(m, now);
                r.resident.push(m);
                // LRU counts insertion as a use.
                r.last_access[m] = now;
                r.inserted_seq[m] = seq;
                seq += 1;
            }
            Op::Access(m) => {
                policy.on_access(m, now);
                r.last_access[m] = now;
                r.counts[m] += 1;
            }
            Op::Evict(m) => {
                policy.on_evict(m);
                r.resident.retain(|&x| x != m);
            }
        }
    }
    r
}

#[test]
fn victim_always_from_candidates_all_policies() {
    for kind in ALL_KINDS {
        prop::check(
            &format!("victim-in-candidates-{}", kind.name()),
            |rng: &mut Rng| {
                let n = prop::usize_in(rng, 2, 8);
                let ops = gen_trace(rng, n, prop::usize_in(rng, 1, 64));
                let seed = rng.next_u64();
                (n, ops, seed)
            },
            |(n, ops, seed)| {
                let mut policy = make_policy(kind, *n, *seed);
                let reference = replay(policy.as_mut(), ops, *n);
                if reference.resident.is_empty() {
                    if policy.victim(&[]).is_some() {
                        return Err("victim from empty candidate set".into());
                    }
                    return Ok(());
                }
                // Try several random candidate subsets of the residents.
                let mut rng = Rng::seeded(seed.wrapping_add(1));
                for _ in 0..8 {
                    let mut candidates: Vec<usize> = reference
                        .resident
                        .iter()
                        .copied()
                        .filter(|_| rng.f64() < 0.7)
                        .collect();
                    candidates.dedup();
                    let victim = policy.victim(&candidates);
                    match victim {
                        None if candidates.is_empty() => {}
                        None => return Err("no victim despite candidates".into()),
                        Some(v) if candidates.contains(&v) => {}
                        Some(v) => return Err(format!("victim {v} not in {candidates:?}")),
                    }
                }
                Ok(())
            },
        );
    }
}

#[test]
fn lru_victim_is_least_recent_under_random_traces() {
    prop::check(
        "lru-least-recent",
        |rng: &mut Rng| {
            let n = prop::usize_in(rng, 2, 8);
            let ops = gen_trace(rng, n, prop::usize_in(rng, 4, 96));
            (n, ops)
        },
        |(n, ops)| {
            let mut policy = Lru::new(*n);
            let reference = replay(&mut policy, ops, *n);
            let candidates = reference.resident.clone();
            if candidates.is_empty() {
                return Ok(());
            }
            let expected = candidates
                .iter()
                .copied()
                .min_by(|&a, &b| {
                    reference.last_access[a]
                        .total_cmp(&reference.last_access[b])
                        .then(a.cmp(&b))
                })
                .unwrap();
            let got = policy.victim(&candidates).unwrap();
            if got != expected {
                return Err(format!(
                    "LRU chose {got}, expected {expected} (last_access {:?})",
                    reference.last_access
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn lfu_victim_is_least_frequent_under_random_traces() {
    prop::check(
        "lfu-least-frequent",
        |rng: &mut Rng| {
            let n = prop::usize_in(rng, 2, 8);
            let ops = gen_trace(rng, n, prop::usize_in(rng, 4, 96));
            (n, ops)
        },
        |(n, ops)| {
            let mut policy = make_policy(PolicyKind::Lfu, *n, 0);
            let reference = replay(policy.as_mut(), ops, *n);
            let candidates = reference.resident.clone();
            if candidates.is_empty() {
                return Ok(());
            }
            let expected = candidates
                .iter()
                .copied()
                .min_by_key(|&m| (reference.counts[m], m))
                .unwrap();
            let got = policy.victim(&candidates).unwrap();
            if got != expected {
                return Err(format!(
                    "LFU chose {got}, expected {expected} (counts {:?})",
                    reference.counts
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn fifo_victim_is_earliest_inserted_under_random_traces() {
    prop::check(
        "fifo-earliest-inserted",
        |rng: &mut Rng| {
            let n = prop::usize_in(rng, 2, 8);
            let ops = gen_trace(rng, n, prop::usize_in(rng, 4, 96));
            (n, ops)
        },
        |(n, ops)| {
            let mut policy = Fifo::new(*n);
            let reference = replay(&mut policy, ops, *n);
            let candidates = reference.resident.clone();
            if candidates.is_empty() {
                return Ok(());
            }
            let expected = candidates
                .iter()
                .copied()
                .min_by_key(|&m| (reference.inserted_seq[m], m))
                .unwrap();
            let got = policy.victim(&candidates).unwrap();
            if got != expected {
                return Err(format!(
                    "FIFO chose {got}, expected {expected} (seq {:?})",
                    reference.inserted_seq
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn evicted_models_are_forgotten_on_reinsert() {
    // FIFO: evict + re-insert moves a model to the back of the queue.
    let mut fifo = Fifo::new(3);
    fifo.on_insert(0, 0.0);
    fifo.on_insert(1, 1.0);
    fifo.on_insert(2, 2.0);
    fifo.on_evict(0);
    fifo.on_insert(0, 3.0);
    assert_eq!(fifo.victim(&[0, 1, 2]), Some(1), "re-inserted 0 must not stay oldest");

    // LRU: evict + re-insert refreshes recency.
    let mut lru = Lru::new(3);
    lru.on_insert(0, 0.0);
    lru.on_insert(1, 1.0);
    lru.on_insert(2, 2.0);
    lru.on_evict(0);
    lru.on_insert(0, 3.0);
    assert_eq!(lru.victim(&[0, 1, 2]), Some(1), "re-inserted 0 is most recent");
}

#[test]
fn random_policy_deterministic_and_covering() {
    prop::check(
        "random-deterministic-covering",
        |rng: &mut Rng| {
            let n = prop::usize_in(rng, 2, 6);
            let candidates: Vec<usize> = (0..n).collect();
            let seed = rng.next_u64();
            (candidates, seed)
        },
        |(candidates, seed)| {
            let mut a = RandomPolicy::new(*seed);
            let mut b = RandomPolicy::new(*seed);
            let mut seen = vec![false; candidates.len()];
            for _ in 0..256 {
                let va = a.victim(candidates).ok_or("no victim")?;
                let vb = b.victim(candidates).ok_or("no victim")?;
                if va != vb {
                    return Err(format!("same seed diverged: {va} vs {vb}"));
                }
                if !candidates.contains(&va) {
                    return Err(format!("victim {va} outside candidates"));
                }
                seen[candidates.iter().position(|&c| c == va).unwrap()] = true;
            }
            if !seen.iter().all(|&s| s) {
                return Err(format!("256 draws missed some candidates: {seen:?}"));
            }
            Ok(())
        },
    );
}

#[test]
fn factory_names_and_empty_candidates() {
    for kind in ALL_KINDS {
        let mut p = make_policy(kind, 4, 7);
        assert_eq!(p.name(), kind.name());
        assert_eq!(p.victim(&[]), None, "{:?} must return None on empty", kind);
    }
}
