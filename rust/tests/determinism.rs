//! Determinism regression pins (DESIGN.md §9): the simulator must be a
//! pure function of (config, seed). Two runs of every registered
//! scenario at G ∈ {1, 2, 4} replicated groups must produce identical
//! `SimReport`s — and the calendar event queue must reproduce the legacy
//! `BinaryHeap` backend bit-for-bit, since both implement the same
//! (time, seq) total order. The parallel bounded-lag executor
//! (`ExecMode::ParallelGroups`, DESIGN.md §13) is held to the same
//! contract: sequential ≡ parallel bit-for-bit across the registry,
//! every replication factor, both queue backends, a non-trivial fault
//! plan, and streaming aggregation.

use computron::cluster::fault::{AutoscalePolicy, FaultEvent, FaultKind, FaultPlan, RetryPolicy};
use computron::config::{ExecMode, GroupSpec, PlacementSpec, RouterKind, SystemConfig};
use computron::sim::{SimCluster, SimReport};
use computron::workload::scenarios;

const SEED: u64 = 0xDE7E_2211;
const DURATION: f64 = 5.0;

fn base_cfg(scenario: &str, g: usize, exec: ExecMode) -> SystemConfig {
    let mut cfg = SystemConfig::workload_experiment(3, 2, 8);
    cfg.scenario = Some(scenario.to_string());
    cfg.exec = exec;
    cfg.placement = Some(PlacementSpec::replicated(
        g,
        cfg.parallel,
        3,
        RouterKind::LeastLoaded,
    ));
    cfg
}

fn run_cfg(cfg: SystemConfig, heap_queue: bool) -> SimReport {
    let (mut sys, _) = SimCluster::from_scenario(cfg, DURATION, SEED).expect("config valid");
    if heap_queue {
        sys.use_binary_heap_queue();
    }
    sys.run()
}

fn run(scenario: &str, g: usize, heap_queue: bool) -> SimReport {
    run_cfg(base_cfg(scenario, g, ExecMode::Sequential), heap_queue)
}

fn run_parallel(scenario: &str, g: usize, heap_queue: bool) -> SimReport {
    run_cfg(base_cfg(scenario, g, ExecMode::ParallelGroups), heap_queue)
}

fn assert_identical(tag: &str, a: &SimReport, b: &SimReport) {
    assert_eq!(a.requests, b.requests, "{tag}: request records differ");
    assert_eq!(a.drops, b.drops, "{tag}: drop records differ");
    assert_eq!(a.swaps, b.swaps, "{tag}: swap records differ");
    assert_eq!(a.swap_stats, b.swap_stats, "{tag}: swap stats differ");
    assert_eq!(a.violations, b.violations, "{tag}: violations differ");
    assert_eq!(a.oom_events, b.oom_events, "{tag}: oom differs");
    assert_eq!(a.mem_high_water, b.mem_high_water, "{tag}: high water differs");
    assert_eq!(a.h2d_bytes, b.h2d_bytes, "{tag}: h2d differs");
    assert_eq!(a.d2h_bytes, b.d2h_bytes, "{tag}: d2h differs");
    assert_eq!(a.events, b.events, "{tag}: event counts differ");
    assert_eq!(a.sim_end, b.sim_end, "{tag}: end times differ");
    assert_eq!(a.fault_stats, b.fault_stats, "{tag}: fault stats differ");
    assert_eq!(a.groups.len(), b.groups.len(), "{tag}: group counts differ");
    for (x, y) in a.groups.iter().zip(&b.groups) {
        assert_eq!(
            (x.requests, x.drops, x.swaps, x.swap_bytes, x.events),
            (y.requests, y.drops, y.swaps, y.swap_bytes, y.events),
            "{tag}: group {} stats differ",
            x.group
        );
        assert_eq!(
            (x.failures, x.downtime, x.recovery_time, x.lost, x.rehomed),
            (y.failures, y.downtime, y.recovery_time, y.lost, y.rehomed),
            "{tag}: group {} fault metrics differ",
            x.group
        );
    }
}

/// Same config + seed ⇒ identical reports, across the whole scenario
/// registry and every replication factor.
#[test]
fn repeated_runs_identical_across_registry() {
    for &scenario in scenarios::names() {
        for g in [1usize, 2, 4] {
            let a = run(scenario, g, false);
            let b = run(scenario, g, false);
            assert_identical(&format!("{scenario}/G={g}"), &a, &b);
            assert!(
                a.requests.len() + a.drops.len() > 0,
                "{scenario}/G={g}: vacuous run"
            );
        }
    }
}

/// The calendar queue's pop order is exactly the heap's (time, seq)
/// order, so whole simulations must agree bit-for-bit.
#[test]
fn calendar_queue_matches_heap_backend_across_registry() {
    for &scenario in scenarios::names() {
        for g in [1usize, 4] {
            let cal = run(scenario, g, false);
            let heap = run(scenario, g, true);
            assert_identical(&format!("{scenario}/G={g}/backend"), &cal, &heap);
        }
    }
}

/// A non-trivial fault plan valid for any group count: group 0 takes a
/// hard failure, recovers, is spot-preempted with a warning, recovers
/// again, and rides a link-degradation window — with retries and the
/// autoscaler armed, so every fault code path is on the calendar.
fn chaotic_plan() -> FaultPlan {
    FaultPlan {
        events: vec![
            FaultEvent { at: 1.0, kind: FaultKind::GroupFail { group: 0 } },
            FaultEvent { at: 1.8, kind: FaultKind::GroupRecover { group: 0 } },
            FaultEvent { at: 2.4, kind: FaultKind::GroupPreempt { group: 0, warning: 0.3 } },
            FaultEvent { at: 3.4, kind: FaultKind::GroupRecover { group: 0 } },
            FaultEvent { at: 3.6, kind: FaultKind::LinkDegrade { group: 0, factor: 3.0 } },
            FaultEvent { at: 4.2, kind: FaultKind::LinkRestore { group: 0 } },
        ],
        retry: RetryPolicy { max_retries: 2, backoff: 0.05 },
        autoscale: Some(AutoscalePolicy {
            interval: 0.4,
            high_queue: 6.0,
            low_queue: 0.5,
            min_active: 1,
        }),
    }
}

fn run_faulted_exec(scenario: &str, g: usize, heap_queue: bool, exec: ExecMode) -> SimReport {
    let mut cfg = base_cfg(scenario, g, exec);
    cfg.faults = Some(chaotic_plan());
    run_cfg(cfg, heap_queue)
}

fn run_faulted(scenario: &str, g: usize, heap_queue: bool) -> SimReport {
    run_faulted_exec(scenario, g, heap_queue, ExecMode::Sequential)
}

/// Fault injection must not cost determinism: with a plan exercising
/// failure, preemption, recovery, link degradation, retries, and the
/// autoscaler, repeated runs and both queue backends still agree
/// bit-for-bit at every replication factor.
#[test]
fn faulted_runs_identical_across_backends_and_group_counts() {
    for &scenario in &["bursty", "zipf"] {
        for g in [1usize, 2, 4] {
            let a = run_faulted(scenario, g, false);
            let b = run_faulted(scenario, g, false);
            assert_identical(&format!("{scenario}/G={g}/faulted/repeat"), &a, &b);
            let heap = run_faulted(scenario, g, true);
            assert_identical(&format!("{scenario}/G={g}/faulted/backend"), &a, &heap);
            assert!(
                a.fault_stats.injected > 0,
                "{scenario}/G={g}: the plan must actually inject"
            );
        }
    }
}

/// `faults: Some(FaultPlan::none())` is the identity: bit-for-bit the
/// same run as `faults: None`, across the scenario registry.
#[test]
fn none_fault_plan_matches_absent_plan_across_registry() {
    for &scenario in scenarios::names() {
        for g in [1usize, 2] {
            let base = run(scenario, g, false);
            let mut cfg = SystemConfig::workload_experiment(3, 2, 8);
            cfg.scenario = Some(scenario.to_string());
            cfg.placement = Some(PlacementSpec::replicated(
                g,
                cfg.parallel,
                3,
                RouterKind::LeastLoaded,
            ));
            cfg.faults = Some(FaultPlan::none());
            let (sys, _) =
                SimCluster::from_scenario(cfg, DURATION, SEED).expect("config valid");
            let none = sys.run();
            assert_identical(&format!("{scenario}/G={g}/none-plan"), &base, &none);
        }
    }
}

fn run_streaming_exec(scenario: &str, g: usize, heap_queue: bool, exec: ExecMode) -> SimReport {
    let cfg = base_cfg(scenario, g, exec);
    let (mut sys, start) = SimCluster::from_scenario(cfg, DURATION, SEED).expect("config valid");
    if heap_queue {
        sys.use_binary_heap_queue();
    }
    sys.set_streaming(start);
    sys.run()
}

fn run_streaming(scenario: &str, g: usize, heap_queue: bool) -> SimReport {
    run_streaming_exec(scenario, g, heap_queue, ExecMode::Sequential)
}

/// Streaming aggregation must be as deterministic as full retention:
/// records are absorbed in event order, so the t-digest latency sketch,
/// the Welford moments behind `Summary::mean`/`std`, and the measured
/// counts are all functions of (config, seed) — across repeated runs
/// *and* across queue backends (the planner's evaluation harness relies
/// on this: candidate scores must not depend on the backend).
fn assert_streaming_identical(tag: &str, a: &SimReport, b: &SimReport) {
    assert_eq!(
        a.streaming_latency, b.streaming_latency,
        "{tag}: streaming latency sketches differ"
    );
    assert_eq!(
        a.streaming_counts, b.streaming_counts,
        "{tag}: measured counts differ"
    );
    assert!(
        a.requests.is_empty() && b.requests.is_empty(),
        "{tag}: streaming runs must not retain request records"
    );
    assert_eq!(a.swap_stats, b.swap_stats, "{tag}: swap stats differ");
    assert_eq!(a.events, b.events, "{tag}: event counts differ");
    assert_eq!(a.sim_end, b.sim_end, "{tag}: end times differ");
    assert_eq!(a.groups.len(), b.groups.len(), "{tag}: group counts differ");
    for (x, y) in a.groups.iter().zip(&b.groups) {
        assert_eq!(
            (x.requests, x.drops, x.swaps, x.swap_bytes, x.events),
            (y.requests, y.drops, y.swaps, y.swap_bytes, y.events),
            "{tag}: group {} stats differ",
            x.group
        );
    }
}

/// Streaming-mode cell: same config + seed ⇒ identical
/// `streaming_latency` / `streaming_counts`, run-to-run and
/// calendar-vs-heap, across the registry.
#[test]
fn streaming_mode_identical_across_registry_and_backends() {
    for &scenario in scenarios::names() {
        for g in [1usize, 4] {
            let a = run_streaming(scenario, g, false);
            let b = run_streaming(scenario, g, false);
            assert_streaming_identical(&format!("{scenario}/G={g}/repeat"), &a, &b);
            let heap = run_streaming(scenario, g, true);
            assert_streaming_identical(&format!("{scenario}/G={g}/backend"), &a, &heap);
            let counts = a.streaming_counts.expect("streaming run reports counts");
            assert!(
                counts.completed + counts.drops > 0,
                "{scenario}/G={g}: vacuous streaming run"
            );
            assert!(
                a.streaming_latency.is_some(),
                "{scenario}/G={g}: missing latency summary"
            );
        }
    }
}

/// The bounded-lag parallel executor (DESIGN.md §13) must reproduce the
/// sequential loop bit-for-bit: across the whole scenario registry,
/// every replication factor, and both queue backends. At G=1 the
/// parallel mode falls back to sequential — the identity must hold
/// there too.
#[test]
fn parallel_exec_matches_sequential_across_registry() {
    for &scenario in scenarios::names() {
        for g in [1usize, 2, 4] {
            for heap in [false, true] {
                let seq = run(scenario, g, heap);
                let par = run_parallel(scenario, g, heap);
                let backend = if heap { "heap" } else { "calendar" };
                assert_identical(&format!("{scenario}/G={g}/{backend}/par"), &seq, &par);
            }
        }
    }
}

/// Fault injection keeps the seq ≡ par contract: the chaotic plan
/// (failure, preemption, recovery, link degradation, retries, the
/// autoscaler) forces the windowed executor through every cluster-scope
/// code path, and the reports must still be bit-identical.
#[test]
fn parallel_exec_matches_sequential_under_faults() {
    for &scenario in &["bursty", "zipf"] {
        for g in [2usize, 4] {
            let seq = run_faulted_exec(scenario, g, false, ExecMode::Sequential);
            let par = run_faulted_exec(scenario, g, false, ExecMode::ParallelGroups);
            assert_identical(&format!("{scenario}/G={g}/faulted/par"), &seq, &par);
            assert!(
                seq.fault_stats.injected > 0,
                "{scenario}/G={g}: the plan must actually inject"
            );
        }
    }
}

/// Streaming aggregation in parallel mode: per-group sketches are
/// merged in group order at finalize, so the t-digest percentiles and
/// Welford moments must equal the sequential run's exactly.
#[test]
fn parallel_streaming_matches_sequential_across_registry() {
    for &scenario in scenarios::names() {
        for g in [2usize, 4] {
            let seq = run_streaming_exec(scenario, g, false, ExecMode::Sequential);
            let par = run_streaming_exec(scenario, g, false, ExecMode::ParallelGroups);
            assert_streaming_identical(&format!("{scenario}/G={g}/streaming/par"), &seq, &par);
        }
    }
}

/// Dedicated placements (every model hosted by exactly one group) take
/// the executor's embarrassingly parallel fast path — one window per
/// group, run to completion. Pin it against sequential, full-retention
/// and streaming.
#[test]
fn parallel_dedicated_fast_path_matches_sequential() {
    let dedicated = |scenario: &str, exec: ExecMode| {
        let mut cfg = SystemConfig::workload_experiment(3, 2, 8);
        cfg.scenario = Some(scenario.to_string());
        cfg.exec = exec;
        let groups = (0..3).map(|m| GroupSpec::new(cfg.parallel, vec![m])).collect();
        cfg.placement = Some(PlacementSpec { router: RouterKind::RoundRobin, groups });
        cfg
    };
    for &scenario in scenarios::names() {
        let seq = run_cfg(dedicated(scenario, ExecMode::Sequential), false);
        let par = run_cfg(dedicated(scenario, ExecMode::ParallelGroups), false);
        assert_identical(&format!("{scenario}/dedicated/par"), &seq, &par);

        let stream = |exec| {
            let (mut sys, start) =
                SimCluster::from_scenario(dedicated(scenario, exec), DURATION, SEED)
                    .expect("config valid");
            sys.set_streaming(start);
            sys.run()
        };
        let seq_s = stream(ExecMode::Sequential);
        let par_s = stream(ExecMode::ParallelGroups);
        assert_streaming_identical(&format!("{scenario}/dedicated/streaming/par"), &seq_s, &par_s);
    }
}
