//! End-to-end real-mode serving tests: launch Computron (engine + worker
//! threads + PJRT execution), serve requests against multiple model
//! instances under a residency cap, and verify correctness of both the
//! numerics (golden argmax) and the swap protocol (no deadlocks, swap
//! counts, distinct per-instance outputs).
//!
//! Requires `make artifacts`; skips gracefully when absent.

use computron::config::EngineConfig;
use computron::runtime::Manifest;
use computron::serving::{Computron, ServeConfig};

fn artifacts() -> Option<std::path::PathBuf> {
    let dir = computron::runtime::manifest::default_dir();
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        None
    }
}

fn launch(num_models: usize, tp: usize, pp: usize, cap: usize) -> Option<(Computron, Manifest)> {
    let dir = artifacts()?;
    let manifest = Manifest::load(&dir).unwrap();
    let mut cfg = ServeConfig::new(&dir, "opt-test", num_models, tp, pp);
    cfg.engine = EngineConfig { resident_cap: cap, max_batch_size: 8, ..EngineConfig::default() };
    Some((Computron::launch(cfg).expect("launch"), manifest))
}

#[test]
fn serve_single_model_matches_golden() {
    let Some((server, manifest)) = launch(1, 1, 1, 1) else { return };
    let golden = &manifest.golden["opt-test"];
    let (b, s) = (golden.batch, golden.seq);
    for row in 0..b {
        let ids = golden.ids[row * s..(row + 1) * s].to_vec();
        let out = server.submit(0, ids).wait().expect("inference succeeds");
        assert_eq!(out.argmax, golden.argmax[row], "row {row}");
        assert!(out.latency > 0.0);
    }
    let stats = server.stats();
    assert_eq!(stats.completed, b as u64);
    assert!(stats.errors.is_empty(), "{:?}", stats.errors);
    server.shutdown();
}

#[test]
fn serve_tp2_pp2_matches_golden() {
    let Some((server, manifest)) = launch(1, 2, 2, 1) else { return };
    let golden = &manifest.golden["opt-test"];
    let ids = golden.ids[..golden.seq].to_vec();
    let out = server.submit(0, ids).wait().expect("inference succeeds");
    assert_eq!(out.argmax, golden.argmax[0]);
    server.shutdown();
}

#[test]
fn swapping_two_models_under_cap_one() {
    // §5.1's real-mode analogue: alternating blocking requests to two
    // instances with only one resident — every request forces a swap.
    let Some((server, manifest)) = launch(2, 1, 1, 1) else { return };
    let golden = &manifest.golden["opt-test"];
    let ids = golden.ids[..golden.seq].to_vec();
    let mut outs = Vec::new();
    for i in 0..6 {
        let model = i % 2;
        let out = server.submit(model, ids.clone()).wait().expect("inference");
        outs.push((model, out));
    }
    let stats = server.stats();
    assert!(stats.errors.is_empty(), "{:?}", stats.errors);
    assert_eq!(stats.completed, 6);
    // Each alternation is a swap: >= 5 loads (first one may be a bare load).
    assert!(stats.swap.loads_completed >= 5, "loads={}", stats.swap.loads_completed);
    assert!(stats.swap.offloads_completed >= 4);
    assert!(stats.mean_load_secs > 0.0);
    // Instance 0 must match golden; instance 1 is a different model and
    // must produce consistent (repeatable) but generally different logits.
    let m0: Vec<_> = outs.iter().filter(|(m, _)| *m == 0).collect();
    let m1: Vec<_> = outs.iter().filter(|(m, _)| *m == 1).collect();
    for (_, out) in &m0 {
        assert_eq!(out.argmax, golden.argmax[0]);
    }
    for (_, out) in m1.windows(2).flatten() {
        let _ = out;
    }
    assert_eq!(m1[0].1.logits, m1[1].1.logits, "same instance must be deterministic");
    assert_ne!(m0[0].1.logits, m1[0].1.logits, "instances must differ");
    server.shutdown();
}

#[test]
fn batched_requests_share_an_entry() {
    let Some((server, manifest)) = launch(1, 1, 1, 1) else { return };
    let golden = &manifest.golden["opt-test"];
    let ids = golden.ids[..golden.seq].to_vec();
    // Fire 8 concurrent requests; after the model loads, queued requests
    // should batch together (and all produce the golden argmax).
    let futs: Vec<_> = (0..8).map(|_| server.submit(0, ids.clone())).collect();
    for f in futs {
        let out = f.wait().expect("inference");
        assert_eq!(out.argmax, golden.argmax[0]);
    }
    let stats = server.stats();
    assert_eq!(stats.completed, 8);
    assert!(stats.errors.is_empty());
    server.shutdown();
}

#[test]
fn rejects_bad_requests() {
    let Some((server, _)) = launch(1, 1, 1, 1) else { return };
    assert!(server.submit(5, vec![1, 2]).wait().is_err(), "unknown model");
    assert!(server.submit(0, vec![]).wait().is_err(), "empty input");
    assert!(server.submit(0, vec![1; 4096]).wait().is_err(), "too long");
    server.shutdown();
}

#[test]
fn three_models_cap_two_all_served() {
    let Some((server, manifest)) = launch(3, 1, 1, 2) else { return };
    let golden = &manifest.golden["opt-test"];
    let ids = golden.ids[..golden.seq].to_vec();
    let futs: Vec<_> = (0..9).map(|i| server.submit(i % 3, ids.clone())).collect();
    for f in futs {
        f.wait().expect("inference");
    }
    let stats = server.stats();
    assert_eq!(stats.completed, 9);
    assert!(stats.errors.is_empty(), "{:?}", stats.errors);
    // With 3 models and cap 2 there must have been at least one eviction.
    assert!(stats.swap.offloads_completed >= 1);
    server.shutdown();
}
