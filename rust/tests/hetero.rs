//! Heterogeneous-fleet (ModelCatalog) system tests:
//!
//! 1. **Equivalence pin** — a homogeneous catalog of N identical entries
//!    (built explicitly, or expanded from the legacy
//!    `{"model","num_models"}` JSON shim) reproduces the legacy
//!    `num_models = N` runs bit-for-bit: same `RequestRecord`s, same
//!    `SwapRecord`s, same event counts and memory marks, across the full
//!    scenario registry, for both the `Async` and `ChunkedPipelined`
//!    load designs.
//! 2. **Per-model swap accounting** — for every catalog entry,
//!    `SwapRecord::bytes` and the per-GPU transfer/memory deltas equal
//!    *that model's* shard bytes (never the fleet max), including under
//!    `ChunkedPipelined` partial loads and cancels.
//! 3. **Size ordering** — in one run, small models swap strictly faster
//!    than large ones.

use computron::config::{
    LoadDesign, ModelCatalog, ModelDeployment, ParallelConfig, SystemConfig,
};
use computron::model::{catalog, max_shard_bytes, shard_grid};
use computron::sim::{Arrival, Driver, SimReport, SimSystem};
use computron::util::json::Json;
use computron::util::prop;
use computron::util::rng::Rng;
use computron::workload::scenarios;

fn run_scenario(cfg: SystemConfig, name: &str, duration: f64) -> SimReport {
    let mut cfg = cfg;
    cfg.scenario = Some(name.to_string());
    let (sys, _) = SimSystem::from_scenario(cfg, duration, 0x4E7E_60).unwrap();
    sys.run()
}

/// The legacy JSON schema (`model` + `num_models`), parsed through the
/// compat shim.
fn legacy_cfg(design: LoadDesign) -> SystemConfig {
    let j = Json::parse(&format!(
        r#"{{"model":"opt-13b","num_models":3,"tp":2,"pp":2,
             "max_batch_size":8,"resident_cap":2,"load_design":"{}"}}"#,
        design.name()
    ))
    .unwrap();
    SystemConfig::from_json(&j).unwrap()
}

/// The same deployment written as an explicit homogeneous catalog.
fn catalog_cfg(design: LoadDesign) -> SystemConfig {
    let models = ModelCatalog::new(vec![
        ModelDeployment::new("opt-13b"),
        ModelDeployment::new("opt-13b"),
        ModelDeployment::new("opt-13b"),
    ]);
    let mut cfg = SystemConfig::hetero_experiment(models, 2, 8);
    cfg.engine.load_design = design;
    cfg
}

#[test]
fn homogeneous_catalog_reproduces_legacy_runs_bit_for_bit() {
    // The tentpole's correctness anchor: per-model shard grids, chunk
    // plans, and cost vectors collapse to the old single-model behaviour
    // when every entry is identical — decision for decision, on every
    // scenario, for both load designs.
    for design in [LoadDesign::AsyncPipelined, LoadDesign::ChunkedPipelined] {
        for &name in scenarios::names() {
            let legacy = run_scenario(legacy_cfg(design), name, 6.0);
            let explicit = run_scenario(catalog_cfg(design), name, 6.0);
            let tag = format!("{name}/{}", design.name());
            assert_eq!(legacy.requests, explicit.requests, "{tag}: request records diverged");
            assert_eq!(legacy.swaps, explicit.swaps, "{tag}: swap records diverged");
            assert_eq!(legacy.events, explicit.events, "{tag}: event counts diverged");
            assert_eq!(legacy.mem_high_water, explicit.mem_high_water, "{tag}: memory diverged");
            assert_eq!(legacy.h2d_bytes, explicit.h2d_bytes, "{tag}: H2D traffic diverged");
            assert_eq!(legacy.d2h_bytes, explicit.d2h_bytes, "{tag}: D2H traffic diverged");
        }
    }
}

/// Per-worker shard bytes for every model of a catalog, indexed
/// `[model][worker]` with the simulator's worker ordering
/// (`pp_rank * tp + tp_rank`).
fn per_worker_shards(cfg: &SystemConfig) -> Vec<Vec<usize>> {
    let (tp, pp) = (cfg.parallel.tp, cfg.parallel.pp);
    cfg.specs()
        .unwrap()
        .iter()
        .map(|spec| {
            let grid = shard_grid(spec, tp, pp).unwrap();
            (0..pp)
                .flat_map(|p| (0..tp).map(move |t| (p, t)))
                .map(|(p, t)| grid[p][t].bytes())
                .collect()
        })
        .collect()
}

#[test]
fn prop_per_model_swap_accounting() {
    // Random heterogeneous catalogs under random traffic: every
    // SwapRecord carries ITS model's shard bytes, and per-GPU link
    // traffic decomposes exactly into per-model loads x that model's
    // per-worker shard (async design; the chunked variant below bounds
    // the same identity through partial loads and cancels).
    let archs = ["opt-125m", "opt-350m", "opt-1.3b", "opt-2.7b"];
    prop::check(
        "hetero-swap-accounting",
        |rng: &mut Rng| {
            let n = prop::usize_in(rng, 2, 4);
            let models: Vec<&str> = (0..n).map(|_| prop::choice(rng, &archs)).collect();
            let cap = prop::usize_in(rng, 1, n);
            let tp = prop::choice(rng, &[1usize, 2]);
            let pp = prop::choice(rng, &[1usize, 2]);
            let reqs: Vec<usize> = (0..40).map(|_| rng.index(n)).collect();
            (models, cap, tp, pp, reqs)
        },
        |(models, cap, tp, pp, reqs)| {
            let catalog_entries =
                models.iter().map(|m| ModelDeployment::new(*m)).collect::<Vec<_>>();
            let mut cfg =
                SystemConfig::hetero_experiment(ModelCatalog::new(catalog_entries), *cap, 4);
            cfg.parallel = ParallelConfig::new(*tp, *pp);
            if cfg.validate().is_err() {
                return Ok(()); // grid does not divide some entry: skip
            }
            let shards = per_worker_shards(&cfg);
            let n = models.len();
            let arrivals: Vec<Arrival> = reqs
                .iter()
                .enumerate()
                .map(|(i, &m)| Arrival { at: 0.05 * i as f64, model: m, input_len: 4 })
                .collect();
            let mut sys = SimSystem::new(cfg, Driver::Open(arrivals)).map_err(|e| e.to_string())?;
            let preload: Vec<usize> = (0..(*cap).min(n)).collect();
            sys.preload(&preload);
            let report = sys.run();
            if report.violations != 0 || report.oom_events != 0 {
                return Err("invariant violation in hetero run".into());
            }
            // 1. Every swap record carries its own model's shard bytes.
            for s in &report.swaps {
                let spec = catalog::by_name(models[s.load_model]).unwrap();
                let expect = max_shard_bytes(&spec, *tp, *pp).unwrap();
                if s.bytes != expect {
                    return Err(format!(
                        "swap of model {} recorded {} bytes, expected its own shard {expect}",
                        s.load_model, s.bytes
                    ));
                }
            }
            // 2. Per-GPU H2D/D2H traffic decomposes into per-model counts
            //    x that model's per-worker shard bytes.
            let mut loads = vec![0u64; n];
            let mut offloads = vec![0u64; n];
            for s in &report.swaps {
                loads[s.load_model] += 1;
                if let Some(v) = s.victim {
                    offloads[v] += 1;
                }
            }
            for w in 0..report.h2d_bytes.len() {
                let h2d: u64 =
                    (0..n).map(|m| loads[m] * shards[m][w] as u64).sum();
                let d2h: u64 =
                    (0..n).map(|m| offloads[m] * shards[m][w] as u64).sum();
                if report.h2d_bytes[w] != h2d {
                    return Err(format!(
                        "worker {w}: H2D {} != per-model decomposition {h2d}",
                        report.h2d_bytes[w]
                    ));
                }
                if report.d2h_bytes[w] != d2h {
                    return Err(format!(
                        "worker {w}: D2H {} != per-model decomposition {d2h}",
                        report.d2h_bytes[w]
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn chunked_hetero_accounting_survives_partial_loads_and_cancels() {
    // Chunked pipeline over a mixed fleet under churny traffic: swap
    // records still carry per-model bytes (cancelled ones included), and
    // per-GPU H2D traffic is bounded by [completed-loads, started-loads]
    // decompositions (a cancelled load moves only a prefix of its shard).
    let models = vec![
        ModelDeployment::new("opt-1.3b"),
        ModelDeployment::new("opt-2.7b"),
        ModelDeployment::new("opt-6.7b"),
    ];
    let mut cfg = SystemConfig::hetero_experiment(ModelCatalog::new(models.clone()), 2, 4);
    cfg.engine.load_design = LoadDesign::ChunkedPipelined;
    cfg.engine.chunk_layers = Some(1);
    // Speculative prefetches create demand-less in-flight loads — the
    // ones `try_cancel_stale_load` preempts when a burst flips priorities.
    cfg.engine.prefetch = true;
    let shards = per_worker_shards(&cfg);
    let arrivals: Vec<Arrival> = (0..60)
        .map(|i| Arrival { at: 0.03 * i as f64, model: (i * 7) % 3, input_len: 4 })
        .collect();
    let mut sys = SimSystem::new(cfg, Driver::Open(arrivals)).unwrap();
    sys.preload(&[0]);
    let report = sys.run();
    assert_eq!(report.violations, 0);
    assert_eq!(report.oom_events, 0);
    let stats = report.swap_stats;
    assert_eq!(stats.loads_started, stats.loads_completed + stats.loads_cancelled);
    for s in &report.swaps {
        let spec = catalog::by_name(&models[s.load_model].model).unwrap();
        let expect = max_shard_bytes(&spec, 2, 2).unwrap();
        assert_eq!(
            s.bytes, expect,
            "model {} (cancelled={}) must report its own shard bytes",
            s.load_model, s.cancelled
        );
    }
    let mut completed = vec![0u64; 3];
    let mut started = vec![0u64; 3];
    for s in &report.swaps {
        started[s.load_model] += 1;
        if !s.cancelled {
            completed[s.load_model] += 1;
        }
    }
    for w in 0..report.h2d_bytes.len() {
        let lo: u64 = (0..3).map(|m| completed[m] * shards[m][w] as u64).sum();
        let hi: u64 = (0..3).map(|m| started[m] * shards[m][w] as u64).sum();
        assert!(
            (lo..=hi).contains(&report.h2d_bytes[w]),
            "worker {w}: H2D {} outside per-model bounds [{lo}, {hi}]",
            report.h2d_bytes[w]
        );
    }
}

#[test]
fn cancelled_swap_records_carry_their_own_bytes() {
    // Deterministic mid-transfer cancellation at the engine level (the
    // sim-level chunked test above only makes cancels *likely*): replay
    // the engine's canonical preemption sequence with per-model costs and
    // check the cancelled record reports the cancelled model's own
    // shard bytes, not the fleet max.
    use computron::config::EngineConfig;
    use computron::coordinator::engine::Engine;
    use computron::coordinator::entry::{Entry, LoadDirection};
    use computron::coordinator::scheduler::ModelCost;
    let mut e = Engine::new(
        2,
        1,
        1,
        EngineConfig {
            max_batch_size: 8,
            resident_cap: 1,
            load_design: LoadDesign::ChunkedPipelined,
            ..EngineConfig::default()
        },
        7,
    );
    e.set_chunks_per_load(vec![4, 4]);
    e.set_cost_model(
        vec![
            ModelCost { swap_cost: 0.1, swap_floor: 0.1, bytes: 111, chunked: false },
            ModelCost { swap_cost: 0.9, swap_floor: 0.9, bytes: 999, chunked: false },
        ],
        0.0,
    );
    e.force_resident(0, 0.0);
    // Request model 1: offload(0) + chunked load(1) + early batch(1).
    e.on_request(1.0, 1, 8);
    let out = e.drain_outbox();
    assert_eq!(out.len(), 3, "offload + load + early batch, got {out:?}");
    let (off0, load1, batch1) = (out[0].id(), out[1].id(), out[2].id());
    e.on_chunk_ack(1.2, load1, 0);
    e.on_batch_done(1.5, batch1);
    // Demand flips back to model 0 while it is still draining.
    e.on_request(2.0, 0, 8);
    assert!(e.drain_outbox().is_empty());
    // Drain completes: model 0 is Blocked on the slot held by the stale
    // half-loaded model 1, so the engine preempts it with a cancel.
    e.on_load_ack(2.5, off0);
    let out = e.drain_outbox();
    assert_eq!(out.len(), 1, "expected a cancel entry, got {out:?}");
    match &out[0] {
        Entry::Load(l) => {
            assert_eq!(l.model, 1);
            assert_eq!(l.dir, LoadDirection::Cancel);
        }
        other => panic!("expected cancel entry, got {other:?}"),
    }
    e.on_load_ack(3.0, out[0].id());
    let recs = e.take_swap_records();
    assert_eq!(recs.len(), 1);
    assert!(recs[0].cancelled);
    assert_eq!(recs[0].load_model, 1);
    assert_eq!(recs[0].bytes, 999, "cancelled record carries model 1's own bytes");
}

#[test]
fn memory_high_water_tracks_the_loaded_models_own_shard() {
    // Cap 1, fleet = [opt-13b, opt-1.3b], traffic ONLY for the small
    // model: the per-GPU high-water mark must equal the SMALL model's
    // shard exactly — a fleet-max accounting bug would charge the 13B
    // footprint.
    let models = ModelCatalog::new(vec![
        ModelDeployment::new("opt-13b"),
        ModelDeployment::new("opt-1.3b"),
    ]);
    let mut cfg = SystemConfig::hetero_experiment(models, 1, 4);
    cfg.parallel = ParallelConfig::new(1, 1);
    let shards = per_worker_shards(&cfg);
    let arrivals: Vec<Arrival> =
        (0..5).map(|i| Arrival { at: 0.5 * i as f64, model: 1, input_len: 4 }).collect();
    let mut sys = SimSystem::new(cfg, Driver::Open(arrivals)).unwrap();
    let report = sys.run();
    assert_eq!(report.requests.len(), 5);
    assert_eq!(report.oom_events, 0);
    for (w, &hw) in report.mem_high_water.iter().enumerate() {
        assert_eq!(
            hw, shards[1][w],
            "worker {w}: high water must be the small model's own shard"
        );
    }
}

#[test]
fn small_models_swap_strictly_faster_than_large_in_one_run() {
    // The hetero bench's core oracle, pinned as a test: alternating
    // blocking requests between a 1.3B and a 13B model (cap 1 — every
    // request swaps). A swap *pair*'s duration is dominated by
    // max(load, offload) and the victim alternates too, so the honest
    // per-model swap-in cost is `time_to_first_chunk` (submission → the
    // model's first chunk resident on every worker — the whole shard,
    // for these monolithic async loads): it must scale with each
    // model's own shard bytes, as must the per-model request latency.
    let models = ModelCatalog::new(vec![
        ModelDeployment::new("opt-1.3b"),
        ModelDeployment::new("opt-13b"),
    ]);
    let mut cfg = SystemConfig::hetero_experiment(models, 1, 1);
    cfg.engine.max_batch_size = 1;
    let mut sys = SimSystem::new(cfg, Driver::AlternatingBlocking {
        models: 2,
        input_len: 2,
        total: 8,
    })
    .unwrap();
    sys.preload(&[1]);
    let report = sys.run();
    assert_eq!(report.requests.len(), 8);
    let mean_ttfc = |m: usize| {
        let v: Vec<f64> = report
            .swaps
            .iter()
            .filter(|s| s.load_model == m && !s.cancelled)
            .map(|s| s.time_to_first_chunk)
            .collect();
        assert!(!v.is_empty(), "model {m} never swapped");
        v.iter().sum::<f64>() / v.len() as f64
    };
    let small = mean_ttfc(0);
    let large = mean_ttfc(1);
    assert!(
        small < large * 0.5,
        "1.3B swap-in ({small:.3}s) must be far faster than 13B swap-in ({large:.3}s)"
    );
    // End-to-end latency orders the same way (batches gate on the load,
    // not the victim's drain).
    let mean_lat = |m: usize| {
        let v: Vec<f64> = report
            .requests
            .iter()
            .filter(|r| r.model == m)
            .map(|r| r.latency())
            .collect();
        v.iter().sum::<f64>() / v.len() as f64
    };
    assert!(mean_lat(0) < mean_lat(1));
}
