//! System-level simulation tests: invariants of the full engine + worker
//! + cluster composition under randomized workloads (the DES equivalent
//! of chaos testing), the §5.2 memory-footprint check, and the
//! engine-invariant oracle swept over every scenario in the
//! `workload::scenarios` registry.

use computron::config::{LoadDesign, PolicyKind, SystemConfig};
use computron::model::{catalog, max_shard_bytes};
use computron::sim::{Arrival, Driver, SimSystem};
use computron::util::prop;
use computron::util::rng::Rng;
use computron::workload::scenarios::{self, ScenarioParams, WorkloadGen};
use computron::workload::GammaWorkload;

fn run_open(cfg: SystemConfig, arrivals: Vec<Arrival>, preload: &[usize]) -> computron::sim::SimReport {
    let mut sys = SimSystem::new(cfg, Driver::Open(arrivals)).unwrap();
    sys.preload(preload);
    sys.run()
}

#[test]
fn gpu_memory_matches_two_model_footprint() {
    // §5.2: "we check that GPU memory usage approximately matches the
    // footprint of two OPT-13B models" (cap 2, TP=2 PP=2).
    let cfg = SystemConfig::workload_experiment(3, 2, 8);
    let w = GammaWorkload::new(vec![2.0, 2.0, 2.0], 1.0, 5);
    let report = run_open(cfg, w.generate(), &[0, 1]);
    let spec = catalog::opt("opt-13b").unwrap();
    let shard = max_shard_bytes(&spec, 2, 2).unwrap();
    for &hw in &report.mem_high_water {
        assert!(hw >= 2 * shard * 9 / 10, "high water {hw} below ~2 shards");
        assert!(hw <= 3 * shard, "high water {hw} above 2 shards + transient");
    }
}

#[test]
fn all_arrivals_complete_under_every_policy() {
    for policy in [PolicyKind::Lru, PolicyKind::Lfu, PolicyKind::Fifo, PolicyKind::Random] {
        let mut cfg = SystemConfig::workload_experiment(3, 2, 8);
        cfg.engine.policy = policy;
        let w = GammaWorkload::new(vec![5.0, 3.0, 1.0], 4.0, 11);
        let arrivals = w.generate();
        let n = arrivals.len();
        let report = run_open(cfg, arrivals, &[0, 1]);
        assert_eq!(report.requests.len(), n, "policy {policy:?} lost requests");
        assert_eq!(report.violations, 0);
        assert_eq!(report.oom_events, 0);
    }
}

#[test]
fn prefetch_preserves_correctness_under_random_load() {
    let mut cfg = SystemConfig::workload_experiment(4, 2, 8);
    cfg.engine.prefetch = true;
    let w = GammaWorkload::new(vec![4.0, 3.0, 2.0, 1.0], 4.0, 23);
    let arrivals = w.generate();
    let n = arrivals.len();
    let report = run_open(cfg, arrivals, &[0, 1]);
    assert_eq!(report.requests.len(), n);
    assert_eq!(report.violations, 0);
    assert_eq!(report.oom_events, 0);
}

#[test]
fn sync_design_preserves_correctness() {
    let mut cfg = SystemConfig::workload_experiment(3, 2, 8);
    cfg.engine.load_design = LoadDesign::SyncPipelined;
    let w = GammaWorkload::new(vec![2.0, 2.0, 2.0], 1.0, 31);
    let arrivals = w.generate();
    let n = arrivals.len();
    let report = run_open(cfg, arrivals, &[0, 1]);
    assert_eq!(report.requests.len(), n);
    assert_eq!(report.violations, 0);
}

#[test]
fn latencies_nonnegative_and_queue_before_done() {
    let cfg = SystemConfig::workload_experiment(3, 2, 8);
    let w = GammaWorkload::new(vec![8.0, 4.0, 2.0], 4.0, 41);
    let report = run_open(cfg, w.generate(), &[0, 1]);
    for r in &report.requests {
        assert!(r.batch_submit >= r.arrival, "submitted before arrival");
        assert!(r.done > r.batch_submit, "done before submission");
        assert!(r.latency() > 0.0);
        assert!(r.queue_time() >= 0.0);
    }
}

#[test]
fn swap_accounting_consistent() {
    let cfg = SystemConfig::workload_experiment(3, 1, 8); // cap 1: heavy swapping
    let w = GammaWorkload::new(vec![2.0, 2.0, 2.0], 0.25, 43);
    let report = run_open(cfg, w.generate(), &[0]);
    let s = report.swap_stats;
    assert_eq!(s.loads_started, s.loads_completed, "loads must drain");
    assert_eq!(s.offloads_started, s.offloads_completed, "offloads must drain");
    assert_eq!(report.swaps.len() as u64, s.loads_completed);
    // H2D bytes across all GPUs == loads × per-worker shard bytes summed.
    let total_h2d: u64 = report.h2d_bytes.iter().sum();
    assert!(total_h2d > 0);
}

#[test]
fn property_random_configs_and_workloads_preserve_invariants() {
    prop::check(
        "sim-chaos",
        |rng: &mut Rng| {
            let models = prop::usize_in(rng, 2, 6);
            let cap = prop::usize_in(rng, 1, models);
            let tp = prop::choice(rng, &[1usize, 2, 4]);
            let pp = prop::choice(rng, &[1usize, 2, 4]);
            let cv = prop::choice(rng, &[0.25, 1.0, 4.0]);
            let batch = prop::choice(rng, &[1usize, 4, 8, 32]);
            let prefetch = rng.f64() < 0.3;
            let rates: Vec<f64> = (0..models).map(|_| prop::f64_in(rng, 0.5, 8.0)).collect();
            let seed = rng.next_u64();
            (models, cap, tp, pp, cv, batch, prefetch, rates, seed)
        },
        |(models, cap, tp, pp, cv, batch, prefetch, rates, seed)| {
            let mut cfg = SystemConfig::workload_experiment(*models, *cap, *batch);
            cfg.parallel = computron::config::ParallelConfig::new(*tp, *pp);
            cfg.engine.prefetch = *prefetch;
            if cfg.validate().is_err() {
                return Ok(()); // invalid grid for opt-13b: skip
            }
            let mut w = GammaWorkload::new(rates.clone(), *cv, *seed);
            w.duration = 5.0; // keep each case fast
            let arrivals = w.generate();
            let n = arrivals.len();
            let mut sys = SimSystem::new(cfg, Driver::Open(arrivals)).map_err(|e| e.to_string())?;
            let preload: Vec<usize> = (0..*cap.min(models)).collect();
            sys.preload(&preload);
            let report = sys.run();
            if report.requests.len() != n {
                return Err(format!("lost requests: {} != {n}", report.requests.len()));
            }
            if report.violations != 0 {
                return Err(format!("{} dependency violations", report.violations));
            }
            if report.oom_events != 0 {
                return Err(format!("{} OOM events", report.oom_events));
            }
            if report.swap_stats.loads_started != report.swap_stats.loads_completed {
                return Err("loads did not drain".into());
            }
            for r in &report.requests {
                if r.latency() <= 0.0 || r.queue_time() < 0.0 {
                    return Err(format!("bad record {r:?}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn deterministic_across_identical_runs() {
    let make = || {
        let cfg = SystemConfig::workload_experiment(3, 2, 8);
        let w = GammaWorkload::new(vec![5.0, 5.0, 5.0], 4.0, 77);
        run_open(cfg, w.generate(), &[0, 1])
    };
    let a = make();
    let b = make();
    assert_eq!(a.requests, b.requests);
    assert_eq!(a.swaps, b.swaps);
    assert_eq!(a.events, b.events);
}

/// Engine-invariant oracle: run one scenario end-to-end and check every
/// cross-layer invariant the design guarantees. Zero load-dependency
/// violations covers "no batch submitted for a non-resident model" (the
/// worker counts exactly that); zero OOM events covers "no eviction of a
/// model whose memory is still needed" (an unsafe eviction leaves the
/// replacement's fill overcommitting the device); completed == arrivals
/// covers "every arrival eventually completes".
fn check_scenario_invariants(name: &str, cfg: SystemConfig, params: &ScenarioParams) {
    let gen = scenarios::by_name(name, params)
        .unwrap_or_else(|| panic!("scenario '{name}' missing from registry"));
    let arrivals = gen.generate();
    let n = arrivals.len();
    assert!(n > 0, "{name}: empty schedule");
    let mut sys = SimSystem::new(cfg, Driver::Open(arrivals)).unwrap();
    sys.preload(&[0, 1]);
    let report = sys.run();

    assert_eq!(report.requests.len(), n, "{name}: arrivals lost");
    assert_eq!(report.violations, 0, "{name}: load-dependency violations");
    assert_eq!(report.oom_events, 0, "{name}: OOM events");
    let s = report.swap_stats;
    assert_eq!(s.loads_started, s.loads_completed, "{name}: loads did not drain");
    assert_eq!(s.offloads_started, s.offloads_completed, "{name}: offloads did not drain");
    assert_eq!(report.swaps.len() as u64, s.loads_completed, "{name}: swap records mismatch");
    for r in &report.requests {
        assert!(r.batch_submit >= r.arrival, "{name}: submitted before arrival");
        assert!(r.done > r.batch_submit, "{name}: done before submission");
    }
}

#[test]
fn every_registry_scenario_preserves_engine_invariants() {
    for &name in scenarios::names() {
        let mut cfg = SystemConfig::workload_experiment(3, 2, 8);
        cfg.scenario = Some(name.to_string());
        cfg.validate().unwrap_or_else(|e| panic!("{name}: {e}"));
        let params = ScenarioParams { duration: 8.0, ..ScenarioParams::new(3, 0x0AC1E) };
        check_scenario_invariants(name, cfg, &params);
    }
}

#[test]
fn scenarios_hold_under_cap_pressure_and_every_policy() {
    // The harshest residency setting (cap 1 of 3), with EVERY policy
    // facing EVERY traffic shape (runs are short, so the full cross
    // product stays cheap).
    let policies = [PolicyKind::Lru, PolicyKind::Lfu, PolicyKind::Fifo, PolicyKind::Random];
    for &name in scenarios::names() {
        let params = ScenarioParams { duration: 5.0, ..ScenarioParams::new(3, 0xCA9) };
        let gen = scenarios::by_name(name, &params).unwrap();
        let arrivals = gen.generate();
        let n = arrivals.len();
        for &policy in &policies {
            let mut cfg = SystemConfig::workload_experiment(3, 1, 8);
            cfg.engine.policy = policy;
            cfg.scenario = Some(name.to_string());
            // preload under cap 1: only model 0.
            let mut sys = SimSystem::new(cfg, Driver::Open(arrivals.clone())).unwrap();
            sys.preload(&[0]);
            let report = sys.run();
            assert_eq!(report.requests.len(), n, "{name}/{policy:?}: arrivals lost under cap 1");
            assert_eq!(report.violations, 0, "{name}/{policy:?}: violations under cap 1");
            assert_eq!(report.oom_events, 0, "{name}/{policy:?}: OOM under cap 1");
        }
    }
}

#[test]
fn from_scenario_wiring_end_to_end() {
    // The config -> registry -> simulator wiring used by the CLI and the
    // scenario-suite bench.
    let mut cfg = SystemConfig::workload_experiment(3, 2, 8);
    cfg.scenario = Some("flash-crowd".to_string());
    let (sys, measure_start) = SimSystem::from_scenario(cfg, 6.0, 0xE2E).unwrap();
    assert!(measure_start > 0.0);
    let report = sys.run();
    assert!(!report.requests.is_empty());
    assert_eq!(report.violations, 0);
    assert!(report.requests.iter().any(|r| r.arrival >= measure_start));

    // Default scenario (None -> "uniform") works too.
    let cfg = SystemConfig::workload_experiment(3, 2, 8);
    let (sys, _) = SimSystem::from_scenario(cfg, 4.0, 0xE2E).unwrap();
    assert!(!sys.run().requests.is_empty());

    // Unknown names error instead of silently falling back.
    let mut cfg = SystemConfig::workload_experiment(3, 2, 8);
    cfg.scenario = Some("not-a-scenario".to_string());
    assert!(cfg.validate().is_err(), "validate must reject unknown scenarios");
    assert!(SimSystem::from_scenario(cfg, 4.0, 1).is_err());
}

#[test]
fn scenario_registry_runs_are_deterministic() {
    for &name in ["zipf", "markov-onoff"].iter() {
        let run = || {
            let mut cfg = SystemConfig::workload_experiment(3, 2, 8);
            cfg.scenario = Some(name.to_string());
            let (sys, _) = SimSystem::from_scenario(cfg, 6.0, 0xD3).unwrap();
            sys.run()
        };
        let a = run();
        let b = run();
        assert_eq!(a.requests, b.requests, "{name}: nondeterministic requests");
        assert_eq!(a.swaps, b.swaps, "{name}: nondeterministic swaps");
        assert_eq!(a.events, b.events, "{name}: nondeterministic event count");
    }
}

#[test]
fn burstier_workloads_swap_less_per_request() {
    // The mechanism behind the paper's Tab 1 pattern: higher CV ⇒
    // consecutive requests hit the same resident model more often.
    let swaps_per_request = |cv: f64| {
        let cfg = SystemConfig::workload_experiment(3, 2, 8);
        let w = GammaWorkload::new(vec![3.0, 3.0, 3.0], cv, 99);
        let report = run_open(cfg, w.generate(), &[0, 1]);
        report.swaps.len() as f64 / report.requests.len() as f64
    };
    let low = swaps_per_request(0.25);
    let high = swaps_per_request(4.0);
    assert!(high < low, "cv=4 ({high}) must swap less per request than cv=0.25 ({low})");
}
