//! Router-policy property tests (ISSUE 5 satellite; DESIGN.md §8):
//!
//! - **round-robin fairness** — over any run of arrivals, one model's
//!   per-group counts differ by at most one;
//! - **least-loaded frugality** — the chosen group is never strictly
//!   costlier than another candidate;
//! - **resident-affinity warmth** — a new swap is never triggered while
//!   a Resident/PartiallyResident replica exists;
//!
//! each checked directly against randomized `GroupView` snapshots, then
//! end-to-end through `SimCluster` across the scenario registry, where
//! resident-affinity's swap avoidance is measured against round-robin's
//! churn on the same workload.

use computron::config::{PlacementSpec, RouterKind, SystemConfig};
use computron::coordinator::router::{self, GroupView};
use computron::coordinator::swap::Residency;
use computron::sim::{Arrival, Driver, SimCluster};
use computron::util::prop;
use computron::util::rng::Rng;
use computron::workload::scenarios;
use std::collections::HashMap;

fn random_views(rng: &mut Rng, groups: usize) -> Vec<GroupView> {
    (0..groups)
        .map(|g| {
            let residency = match rng.index(5) {
                0 => Residency::Resident,
                1 => Residency::PartiallyResident { loaded: 1, total: 4 },
                2 => Residency::Loading,
                3 => Residency::Offloading,
                _ => Residency::Offloaded,
            };
            GroupView {
                group: g,
                queue_cost: rng.index(20) as f64,
                residency,
                swap_cost: 0.05 * (1 + rng.index(40)) as f64,
            }
        })
        .collect()
}

#[test]
fn prop_round_robin_fairness() {
    // Per model, per-group counts over K routed arrivals differ by <= 1.
    prop::check(
        "round-robin-fairness",
        |rng: &mut Rng| {
            let groups = prop::usize_in(rng, 2, 5);
            let models = prop::usize_in(rng, 1, 4);
            let arrivals: Vec<usize> = (0..60).map(|_| rng.index(models)).collect();
            (groups, models, arrivals)
        },
        |(groups, models, arrivals)| {
            let mut r = router::by_name("round-robin").unwrap();
            let views: Vec<GroupView> = (0..*groups)
                .map(|g| GroupView {
                    group: g,
                    queue_cost: g as f64, // load must not matter
                    residency: Residency::Offloaded,
                    swap_cost: 1.0,
                })
                .collect();
            let mut counts: HashMap<(usize, usize), usize> = HashMap::new();
            for &m in arrivals {
                let g = r.route(m, &views);
                *counts.entry((m, g)).or_insert(0) += 1;
            }
            for m in 0..*models {
                let per_group: Vec<usize> =
                    (0..*groups).map(|g| counts.get(&(m, g)).copied().unwrap_or(0)).collect();
                let (lo, hi) = (
                    per_group.iter().min().unwrap(),
                    per_group.iter().max().unwrap(),
                );
                if hi - lo > 1 {
                    return Err(format!("model {m}: unfair split {per_group:?}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_least_loaded_never_picks_strictly_costlier() {
    prop::check(
        "least-loaded-frugal",
        |rng: &mut Rng| {
            let groups = prop::usize_in(rng, 1, 6);
            random_views(rng, groups)
        },
        |views| {
            let mut r = router::by_name("least-loaded").unwrap();
            let chosen = r.route(0, views);
            let cost = views.iter().find(|v| v.group == chosen).unwrap().queue_cost;
            let min = views.iter().map(|v| v.queue_cost).fold(f64::INFINITY, f64::min);
            if cost > min {
                return Err(format!(
                    "picked group {chosen} at cost {cost} with a cheaper candidate ({min})"
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_resident_affinity_never_swaps_when_resident_replica_exists() {
    prop::check(
        "resident-affinity-warmth",
        |rng: &mut Rng| {
            let groups = prop::usize_in(rng, 1, 6);
            random_views(rng, groups)
        },
        |views| {
            let mut r = router::by_name("resident-affinity").unwrap();
            let chosen = r.route(0, views);
            let chosen_view = views.iter().find(|v| v.group == chosen).unwrap();
            let any_resident = views.iter().any(|v| {
                matches!(
                    v.residency,
                    Residency::Resident | Residency::PartiallyResident { .. }
                )
            });
            // Routing to a warm group never starts a new swap; routing to
            // a cold one does. So: a resident replica anywhere means the
            // chosen group must be warm.
            if any_resident && !chosen_view.warm() {
                return Err(format!(
                    "chose cold group {chosen} despite a resident replica: {views:?}"
                ));
            }
            // And among all-cold candidates the cheapest swap wins.
            if !views.iter().any(GroupView::warm) {
                let min = views.iter().map(|v| v.swap_cost).fold(f64::INFINITY, f64::min);
                if chosen_view.swap_cost > min {
                    return Err(format!(
                        "all-cold tie broken away from the cheapest swap: {views:?}"
                    ));
                }
            }
            Ok(())
        },
    );
}

/// Two replicated groups, cap 1, two models, tightly alternating opens:
/// resident-affinity discovers the stable model→group partition (one
/// swap-in per model, ever), while round-robin keeps both groups
/// churning (§5.1's worst case on each).
#[test]
fn affinity_partitions_where_round_robin_churns() {
    let run = |kind: RouterKind| {
        let mut cfg = SystemConfig::workload_experiment(2, 1, 8);
        cfg.placement = Some(PlacementSpec::replicated(2, cfg.parallel, 2, kind));
        let arrivals: Vec<Arrival> = (0..40)
            .map(|i| Arrival { at: 0.15 * i as f64, model: i % 2, input_len: 8 })
            .collect();
        // Cold start: no preload, so the router's first decisions place
        // the models.
        let sys = SimCluster::new(cfg, Driver::Open(arrivals)).unwrap();
        sys.run()
    };
    let affinity = run(RouterKind::ResidentAffinity);
    assert_eq!(affinity.requests.len(), 40);
    assert_eq!(affinity.violations, 0);
    assert_eq!(
        affinity.swap_stats.loads_completed, 2,
        "affinity loads each model exactly once and then sticks: {:?}",
        affinity.swaps
    );
    let round_robin = run(RouterKind::RoundRobin);
    assert_eq!(round_robin.requests.len(), 40);
    assert!(
        round_robin.swap_stats.loads_completed > affinity.swap_stats.loads_completed * 3,
        "round-robin must churn where affinity sticks: rr {} vs affinity {}",
        round_robin.swap_stats.loads_completed,
        affinity.swap_stats.loads_completed
    );
}

#[test]
fn routers_hold_invariants_across_the_scenario_registry() {
    // Every scenario × every router on a 2-group replicated placement:
    // runs drain, stay deterministic, and account for every request.
    for &name in scenarios::names() {
        for &kind in router::KINDS.iter() {
            let run = || {
                let mut cfg = SystemConfig::workload_experiment(3, 2, 8);
                cfg.scenario = Some(name.to_string());
                cfg.placement = Some(PlacementSpec::replicated(2, cfg.parallel, 3, kind));
                let (sys, _) = SimCluster::from_scenario(cfg, 5.0, 0x40_0735).unwrap();
                sys.run()
            };
            let report = run();
            let tag = format!("{name}/{}", kind.name());
            assert_eq!(report.violations, 0, "{tag}");
            assert_eq!(report.oom_events, 0, "{tag}");
            assert_eq!(report.groups.len(), 2, "{tag}");
            let s = report.swap_stats;
            assert_eq!(s.loads_started, s.loads_completed + s.loads_cancelled, "{tag}");
            assert_eq!(s.offloads_started, s.offloads_completed, "{tag}");
            assert_eq!(
                report.groups.iter().map(|g| g.requests).sum::<usize>(),
                report.requests.len(),
                "{tag}"
            );
            let again = run();
            assert_eq!(report.requests, again.requests, "{tag}: non-deterministic");
            assert_eq!(report.events, again.events, "{tag}: non-deterministic");
        }
    }
}
