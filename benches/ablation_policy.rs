//! Replacement-policy ablation (§4 uses LRU): LRU vs LFU vs FIFO vs
//! Random victim selection under the skewed bursty workload where policy
//! matters most — (10,10,1) rates at CV=4, 3 models, cap 2.
//!
//! Also exercises the engine's predictability claim: under LRU, bursts to
//! the same model re-hit the resident copy, so swap counts stay low.

#[path = "common.rs"]
mod common;

use computron::config::{PolicyKind, SystemConfig};
use computron::sim::{Driver, SimSystem};
use computron::util::bench::{section, table};
use computron::util::json::Json;
use computron::workload::GammaWorkload;

fn main() {
    let fast = common::fast_mode();
    let seeds: u64 = if fast { 3 } else { 5 };
    section("Ablation: replacement policy under skewed bursty load (3 models, cap 2)");
    let mut rows = Vec::new();
    let mut report_pairs: Vec<(&str, computron::util::json::Json)> = Vec::new();
    let mut lru_mean = 0.0;
    let mut results = Vec::new();

    for policy in [PolicyKind::Lru, PolicyKind::Lfu, PolicyKind::Fifo, PolicyKind::Random] {
        // Average over several seeds: policies interact with arrival noise.
        let mut means = Vec::new();
        let mut swaps = 0usize;
        for seed in 0..seeds {
            let mut cfg = SystemConfig::workload_experiment(3, 2, 8);
            cfg.engine.policy = policy;
            let workload = GammaWorkload::new(vec![10.0, 10.0, 1.0], 4.0, 0xAB1E + seed);
            let arrivals = workload.generate();
            let start = workload.measure_start();
            let mut sys = SimSystem::new(cfg, Driver::Open(arrivals)).unwrap();
            sys.preload(&[0, 1]);
            let r = sys.run();
            means.push(r.mean_latency_from(start));
            swaps += r.swaps.len();
        }
        let mean = means.iter().sum::<f64>() / means.len() as f64;
        if policy == PolicyKind::Lru {
            lru_mean = mean;
        }
        rows.push(vec![
            policy.name().to_string(),
            common::fmt_s(mean),
            format!("{:.1}", swaps as f64 / seeds as f64),
        ]);
        results.push((policy, mean));
        report_pairs.push((policy.name(), mean.into()));
    }
    table(&["policy", "mean latency (s)", "swaps/run"], &rows);

    // LRU should be competitive with the best policy (the paper picked it).
    let best = results.iter().map(|(_, m)| *m).fold(f64::MAX, f64::min);
    assert!(
        lru_mean <= best * 1.35,
        "LRU ({lru_mean}) should be within 35% of the best policy ({best})"
    );
    println!("shape checks passed: LRU competitive under skewed bursty load");

    let mut payload = Json::from_pairs(report_pairs);
    payload.set("experiment", "ablation_policy".into());
    payload.set("fast", fast.into());
    common::save_report("ablation_policy", payload.clone());
    common::save_bench_json("ablation_policy", payload);
}
