//! Fleet scale — the host-memory hierarchy's headline experiment
//! (DESIGN.md §12): grow a fine-tuned-variant catalog 10 → 100 → 1000
//! entries over a *fixed* pinned-host budget and a zipf long-tail
//! workload, with variants sharing a handful of base architectures.
//!
//! Deterministic oracles asserted before the sweep:
//!
//! - **Delta exactness** — every swap-in of a variant whose base is GPU
//!   resident moves exactly `scale_count(shard_bytes, delta_fraction)`
//!   bytes, and its `delta_bytes_saved` is exactly the complement;
//! - **Tier cost ordering** — an NVMe-cold first swap is strictly slower
//!   (> 2x here) than the same model's host-warm swaps.
//!
//! Oracles asserted on every fleet cell:
//!
//! - engine invariants (no dependency violations, no OOM, swaps
//!   drained) and host-tier budget respected (high water <= budget);
//! - per-record byte provenance: delta-form records carry exact delta
//!   bytes, full-form records carry the full shard;
//! - full-form host hits are cheaper on average than NVMe misses;
//! - **dedup goodput** — at 1000 models under the fixed budget, the
//!   delta-sharing catalog strictly beats the same fleet with lineage
//!   stripped (every variant stored full-form).
//!
//! ```bash
//! cargo bench --bench fleet_scale              # full sweep
//! cargo bench --bench fleet_scale -- --fast    # CI smoke subset
//! ```

#[path = "common.rs"]
mod common;

use computron::cluster::{HostPolicyKind, SwapTier};
use computron::config::{
    HostConfig, LoadDesign, ModelCatalog, ModelDeployment, ParallelConfig, SchedulerKind,
    SystemConfig,
};
use computron::coordinator::engine::SwapRecord;
use computron::model::shard::scale_count;
use computron::model::shard_grid;
use computron::sim::{Driver, SimCluster, SimSystem};
use computron::util::bench::{section, table};
use computron::util::json::Json;
use computron::workload::scenarios::{self, ScenarioParams, WorkloadGen};

const SEED: u64 = 0xF1EE_75CA;

/// Fixed pinned-host budget for every fleet size: fits the 10-model
/// fleet outright, most of the 100-model fleet in delta form, and a
/// small fraction of the 1000-model fleet — eviction pressure is the
/// experiment.
const HOST_BUDGET: usize = 32_000_000_000;

/// Fraction of parameters each fine-tune touches.
const DELTA_FRACTION: f64 = 0.1;

/// Base architectures shared by the whole fleet (variants reference
/// their family's standalone entry).
const FAMILIES: [&str; 4] = ["opt-125m", "opt-350m", "opt-1.3b", "opt-2.7b"];

/// `n`-entry fleet: one standalone base per family, then fine-tuned
/// variants round-robin across families. `dedup = false` strips the
/// lineage (every variant stored and swapped full-form) — the control
/// arm of the dedup-goodput oracle.
fn fleet(n: usize, dedup: bool) -> ModelCatalog {
    assert!(n >= FAMILIES.len());
    let mut models = Vec::with_capacity(n);
    for fam in FAMILIES {
        models.push(ModelDeployment::new(fam).with_slo(1.0));
    }
    for k in FAMILIES.len()..n {
        let fam = FAMILIES[k % FAMILIES.len()];
        let mut d = ModelDeployment::new(fam).with_slo(1.0);
        if dedup {
            d = d.with_base(fam, DELTA_FRACTION);
        }
        models.push(d);
    }
    ModelCatalog::new(models)
}

fn fleet_cfg(n: usize, dedup: bool, policy: HostPolicyKind) -> SystemConfig {
    let mut cfg = SystemConfig::hetero_experiment(fleet(n, dedup), 4, 8);
    // One worker keeps the per-cell cost linear in the trace, not the
    // grid; the sharded delta path is pinned by the exactness stage.
    cfg.parallel = ParallelConfig::new(1, 1);
    cfg.engine.scheduler = SchedulerKind::Shed;
    cfg.engine.load_design = LoadDesign::ChunkedPipelined;
    cfg.host = Some(HostConfig { budget: HOST_BUDGET, policy, ..HostConfig::default() });
    cfg
}

struct FleetCell {
    goodput: f64,
    attained: usize,
    requests: usize,
    drops: usize,
    hit_rate: f64,
    evictions: u64,
    nvme_gb: f64,
    host_delta_gb: f64,
    gpu_delta_gb: f64,
    mean_hit_s: f64,
    mean_miss_s: f64,
}

fn run_fleet(n: usize, dedup: bool, policy: HostPolicyKind, duration: f64) -> FleetCell {
    let cfg = fleet_cfg(n, dedup, policy);
    // Per-entry ground truth for the byte-provenance oracle, computed
    // before the config moves into the simulator.
    let bases = cfg.resolved_bases().expect("fleet lineage resolves");
    let fractions: Vec<f64> = cfg.models.iter().map(|d| d.delta_fraction).collect();
    let full: Vec<usize> = cfg
        .models
        .specs()
        .expect("fleet resolves")
        .iter()
        .map(|spec| shard_grid(spec, 1, 1).expect("1x1 grid")[0][0].bytes())
        .collect();

    let params = ScenarioParams {
        num_models: n,
        duration,
        seed: SEED,
        // Fixed aggregate offered load (~24 req/s) regardless of fleet
        // size, so cells differ only in how the tail spreads.
        rate_scale: 12.0 / n as f64,
        rate_shares: cfg.models.rate_shares(),
        warmup: 0,
        input_len: 4,
    };
    let gen = scenarios::by_name("zipf", &params).expect("zipf registered");
    let arrivals = gen.generate();
    let start = gen.measure_start();
    let mut sys = SimCluster::new(cfg, Driver::Open(arrivals)).expect("config valid");
    sys.preload(&[0]);
    let report = sys.run();

    let tag = format!("fleet n={n} dedup={dedup} policy={}", policy.name());
    assert_eq!(report.violations, 0, "{tag}: load-dependency violations");
    assert_eq!(report.oom_events, 0, "{tag}: OOM events");
    let s = report.swap_stats;
    assert_eq!(s.loads_started, s.loads_completed + s.loads_cancelled, "{tag}: loads drained");
    assert_eq!(report.host.len(), 1, "{tag}: one per-group host tier");
    let host = &report.host[0];
    assert_eq!(host.budget, HOST_BUDGET, "{tag}");
    assert!(host.high_water <= HOST_BUDGET, "{tag}: pinned past the budget");

    // Byte provenance: every completed record is either an exact delta
    // over its base or the exact full shard.
    let (mut hit_n, mut hit_s, mut miss_n, mut miss_s) = (0u64, 0.0f64, 0u64, 0.0f64);
    for sw in report.swaps.iter().filter(|sw| !sw.cancelled) {
        let m = sw.load_model;
        if sw.delta_bytes_saved > 0 {
            let base = bases[m].expect("delta record for a standalone entry");
            assert_eq!(full[base], full[m], "{tag}: family shares one architecture");
            assert_eq!(
                sw.bytes,
                scale_count(full[m], fractions[m]),
                "{tag}: delta record must move exactly the delta bytes"
            );
            assert_eq!(sw.delta_bytes_saved, full[m] - sw.bytes, "{tag}: savings complement");
        } else {
            assert_eq!(sw.bytes, full[m], "{tag}: full-form record must move the full shard");
            match sw.tier {
                SwapTier::HostHit => {
                    hit_n += 1;
                    hit_s += sw.duration();
                }
                SwapTier::NvmeMiss => {
                    miss_n += 1;
                    miss_s += sw.duration();
                }
            }
        }
    }
    let mean_hit_s = if hit_n > 0 { hit_s / hit_n as f64 } else { 0.0 };
    let mean_miss_s = if miss_n > 0 { miss_s / miss_n as f64 } else { 0.0 };
    if hit_n > 0 && miss_n > 0 {
        assert!(
            mean_miss_s > mean_hit_s,
            "{tag}: NVMe misses ({mean_miss_s:.3} s) must cost more than host hits ({mean_hit_s:.3} s)"
        );
    }

    let attained =
        report.requests.iter().filter(|r| r.arrival >= start && r.attained()).count();
    let gpu_delta: u64 = report.groups.iter().map(|g| g.delta_bytes_saved).sum();
    FleetCell {
        goodput: attained as f64 / duration,
        attained,
        requests: report.requests.iter().filter(|r| r.arrival >= start).count(),
        drops: report.drops.iter().filter(|d| d.arrival >= start).count(),
        hit_rate: host.hit_rate(),
        evictions: host.stats.evictions,
        nvme_gb: host.stats.nvme_bytes as f64 / 1e9,
        host_delta_gb: host.stats.delta_bytes_saved as f64 / 1e9,
        gpu_delta_gb: gpu_delta as f64 / 1e9,
        mean_hit_s,
        mean_miss_s,
    }
}

/// Delta-exactness stage: a 2x2-sharded variant cycling against its
/// resident base must move exactly the per-worker delta bytes, chunked.
fn delta_exactness_stage() -> (usize, usize, usize) {
    let catalog = ModelCatalog::new(vec![
        ModelDeployment::new("opt-1.3b"),
        ModelDeployment::new("opt-1.3b").with_base("opt-1.3b", DELTA_FRACTION),
        ModelDeployment::new("opt-1.3b"),
    ]);
    let mut cfg = SystemConfig::hetero_experiment(catalog, 2, 8);
    cfg.engine.load_design = LoadDesign::ChunkedPipelined;
    cfg.host = Some(HostConfig { warm_start: true, ..HostConfig::default() });

    let spec = cfg.models.specs().expect("resolves")[0].clone();
    let grid = shard_grid(&spec, 2, 2).expect("2x2 grid divides");
    let full_max =
        grid.iter().flatten().map(|shard| shard.bytes()).max().expect("non-empty grid");
    let eff_max = grid
        .iter()
        .flatten()
        .map(|shard| scale_count(shard.bytes(), DELTA_FRACTION))
        .max()
        .expect("non-empty grid");

    let mut sys =
        SimSystem::new(cfg, Driver::AlternatingBlocking { models: 3, input_len: 2, total: 9 })
            .expect("config valid");
    sys.preload(&[0]);
    let report = sys.run();
    assert_eq!(report.violations, 0);

    let mut variant_swaps = 0usize;
    for sw in report.swaps.iter().filter(|sw| !sw.cancelled) {
        match sw.load_model {
            1 => {
                variant_swaps += 1;
                assert_eq!(sw.bytes, eff_max, "variant over resident base: delta bytes only");
                assert_eq!(sw.delta_bytes_saved, full_max - eff_max, "exact H2D savings");
                assert_ne!(sw.victim, Some(0), "a variant never evicts its own base");
            }
            2 => {
                assert_eq!(sw.bytes, full_max, "standalone entries move the full shard");
                assert_eq!(sw.delta_bytes_saved, 0);
            }
            _ => {}
        }
    }
    assert!(variant_swaps >= 2, "the cycle must swap the variant repeatedly");
    let saved: u64 = report.groups.iter().map(|g| g.delta_bytes_saved).sum();
    assert_eq!(saved, variant_swaps as u64 * (full_max - eff_max) as u64, "group ledger agrees");
    (full_max, eff_max, variant_swaps)
}

/// Tier-cost stage: the one NVMe-cold swap of the run is strictly (and
/// decisively) slower than the same model's host-warm swaps.
fn tier_cost_stage() -> (f64, f64) {
    let mut cfg = SystemConfig::swap_experiment(1, 1);
    cfg.host = Some(HostConfig::default()); // cold start, default NVMe link
    let mut sys =
        SimSystem::new(cfg, Driver::AlternatingBlocking { models: 2, input_len: 2, total: 8 })
            .expect("config valid");
    sys.preload(&[1]);
    let report = sys.run();

    let cold: Vec<&SwapRecord> = report
        .swaps
        .iter()
        .filter(|sw| !sw.cancelled && sw.tier == SwapTier::NvmeMiss)
        .collect();
    assert_eq!(cold.len(), 1, "only the first un-preloaded load is host-cold");
    let cold_s = cold[0].duration();
    let warm_s = report
        .swaps
        .iter()
        .filter(|sw| {
            !sw.cancelled && sw.tier == SwapTier::HostHit && sw.load_model == cold[0].load_model
        })
        .map(SwapRecord::duration)
        .fold(f64::INFINITY, f64::min);
    assert!(warm_s.is_finite(), "the cold model must swap host-warm later in the cycle");
    assert!(
        cold_s > 2.0 * warm_s,
        "NVMe-cold swap ({cold_s:.3} s) must dominate the host-warm one ({warm_s:.3} s)"
    );
    (cold_s, warm_s)
}

fn cell_row(n: usize, dedup: bool, policy: HostPolicyKind, c: &FleetCell) -> Vec<String> {
    vec![
        n.to_string(),
        if dedup { "delta".into() } else { "full".into() },
        policy.name().to_string(),
        format!("{:.1}", c.goodput),
        c.attained.to_string(),
        c.requests.to_string(),
        c.drops.to_string(),
        format!("{:.1}%", 100.0 * c.hit_rate),
        c.evictions.to_string(),
        format!("{:.1}", c.nvme_gb),
        format!("{:.1}", c.host_delta_gb),
        format!("{:.2}", c.gpu_delta_gb),
        common::fmt_s(c.mean_hit_s),
        common::fmt_s(c.mean_miss_s),
    ]
}

fn cell_json(n: usize, dedup: bool, policy: HostPolicyKind, c: &FleetCell) -> Json {
    Json::from_pairs(vec![
        ("models", n.into()),
        ("dedup", dedup.into()),
        ("policy", policy.name().into()),
        ("goodput", c.goodput.into()),
        ("attained", c.attained.into()),
        ("requests", c.requests.into()),
        ("drops", c.drops.into()),
        ("host_hit_rate", c.hit_rate.into()),
        ("host_evictions", c.evictions.into()),
        ("nvme_gb", c.nvme_gb.into()),
        ("host_delta_gb_saved", c.host_delta_gb.into()),
        ("gpu_delta_gb_saved", c.gpu_delta_gb.into()),
        ("mean_hit_s", c.mean_hit_s.into()),
        ("mean_miss_s", c.mean_miss_s.into()),
    ])
}

fn main() {
    let fast = common::fast_mode();
    let duration = if fast { 4.0 } else { 12.0 };
    let fleet_sizes = [10usize, 100, 1000];

    section("Fleet scale: host-memory hierarchy under a growing variant catalog");

    let (full_max, eff_max, variant_swaps) = delta_exactness_stage();
    println!(
        "delta exactness: {variant_swaps} variant swaps moved {eff_max} B each \
         (full shard {full_max} B, fraction {DELTA_FRACTION})"
    );
    let (cold_s, warm_s) = tier_cost_stage();
    println!("tier cost: NVMe-cold {cold_s:.3} s vs host-warm {warm_s:.3} s");

    let mut rows: Vec<Vec<String>> = Vec::new();
    let mut cells_json: Vec<Json> = Vec::new();

    // Catalog scaling sweep under the fixed budget.
    let mut dedup_1000: Option<FleetCell> = None;
    for &n in &fleet_sizes {
        let cell = run_fleet(n, true, HostPolicyKind::WeightedCost, duration);
        rows.push(cell_row(n, true, HostPolicyKind::WeightedCost, &cell));
        cells_json.push(cell_json(n, true, HostPolicyKind::WeightedCost, &cell));
        if n == 1000 {
            dedup_1000 = Some(cell);
        }
    }

    // Host-policy sweep at the mid fleet size (full mode only).
    if !fast {
        for policy in [HostPolicyKind::Lru, HostPolicyKind::Lfu] {
            let cell = run_fleet(100, true, policy, duration);
            rows.push(cell_row(100, true, policy, &cell));
            cells_json.push(cell_json(100, true, policy, &cell));
        }
    }

    // Dedup-goodput oracle: same 1000-model zipf stream and budget, with
    // and without base sharing.
    let dedup = dedup_1000.expect("1000-model cell swept above");
    let full_form = run_fleet(1000, false, HostPolicyKind::WeightedCost, duration);
    rows.push(cell_row(1000, false, HostPolicyKind::WeightedCost, &full_form));
    cells_json.push(cell_json(1000, false, HostPolicyKind::WeightedCost, &full_form));
    assert!(
        dedup.goodput > full_form.goodput,
        "dedup fleet must strictly beat full-form storage at 1000 models \
         ({:.2} vs {:.2} req/s)",
        dedup.goodput,
        full_form.goodput
    );
    assert!(
        dedup.host_delta_gb > 0.0,
        "the 1000-model dedup fleet must stage some variants in delta form"
    );
    println!(
        "dedup goodput at 1000 models: {:.2} req/s (delta) vs {:.2} req/s (full-form), \
         host hit rate {:.1}% vs {:.1}%",
        dedup.goodput,
        full_form.goodput,
        100.0 * dedup.hit_rate,
        100.0 * full_form.hit_rate
    );

    table(
        &[
            "models",
            "storage",
            "policy",
            "goodput (req/s)",
            "attained",
            "served",
            "drops",
            "host hit",
            "evict",
            "NVMe GB",
            "host dGB",
            "gpu dGB",
            "hit s",
            "miss s",
        ],
        &rows,
    );
    println!(
        "\noracles held: exact delta bytes over resident bases, cold >> warm tier cost, \
         budget respected, dedup goodput strictly ahead at 1000 models"
    );

    let payload = Json::from_pairs(vec![
        ("experiment", "fleet_scale".into()),
        ("duration", duration.into()),
        ("fast", fast.into()),
        ("host_budget", HOST_BUDGET.into()),
        ("delta_fraction", DELTA_FRACTION.into()),
        ("full_shard_bytes", full_max.into()),
        ("delta_shard_bytes", eff_max.into()),
        ("cold_swap_s", cold_s.into()),
        ("warm_swap_s", warm_s.into()),
        ("dedup_goodput", dedup.goodput.into()),
        ("full_form_goodput", full_form.goodput.into()),
        ("cells", Json::Arr(cells_json)),
    ]);
    common::save_report("fleet_scale", payload.clone());
    common::save_bench_json("fleet_scale", payload);
}
