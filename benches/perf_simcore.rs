//! Simulator-core throughput — the event-queue/hot-path overhaul's
//! headline numbers (DESIGN.md §9):
//!
//! 1. **Queue churn**: hold-one-pop-one churn against an `EventQueue`
//!    pre-loaded with N pending events, calendar backend vs the legacy
//!    `BinaryHeap` backend. This isolates the O(1)-vs-O(log n) queue
//!    cost — the ≥10× claim lives here, at trace-scale N.
//! 2. **End-to-end registry sweep**: every workload scenario × G ∈ {1, 4}
//!    on the 4-model heterogeneous overload fleet (the `group_scaling`
//!    cell), streaming aggregation on, reporting DES events/sec.
//! 3. **Calendar vs heap end-to-end** on the 4-group `zipf` overload
//!    cell — the whole-system speedup attributable to the queue.
//! 4. **Parallel vs sequential executor** on dedicated placements (each
//!    model hosted by exactly one group — the bounded-lag executor's
//!    fast path, DESIGN.md §13) at G ∈ {2, 4}, with the seq ≡ par
//!    bit-equality oracle asserted in-bench before the speedup is
//!    reported.
//!
//! Peak RSS (`VmHWM`) is sampled before and after every end-to-end cell
//! so each cell's high-water growth — e.g. the parallel cells' extra
//! thread stacks — is attributable to it; the final mark is also
//! reported. Results land in `BENCH_perf_simcore.json` (override with
//! `-- --json <path>`); the committed copy is the CI perf-smoke
//! baseline (EXPERIMENTS.md §Perf).
//!
//! ```bash
//! cargo bench --bench perf_simcore              # full sweep
//! cargo bench --bench perf_simcore -- --fast    # CI smoke subset
//! ```

#[path = "common.rs"]
mod common;

use std::time::Instant;

use computron::cluster::{EventQueue, QueueBackend};
use computron::config::{
    ExecMode, GroupSpec, ModelCatalog, ModelDeployment, PlacementSpec, RouterKind, SchedulerKind,
    SystemConfig,
};
use computron::sim::{Driver, SimCluster, SimReport};
use computron::util::bench::{black_box, fmt_rate, section, table};
use computron::util::json::Json;
use computron::workload::scenarios::{self, ScenarioParams, WorkloadGen};

const SEED: u64 = 0x6A0C_5CA1;
const OVERLOAD_RATE_SCALE: f64 = 60.0;

/// The `group_scaling` fleet: hot small models, cold large tail
/// (4:3:2:1 shares), uniform 1 s SLO.
fn fleet() -> ModelCatalog {
    ModelCatalog::new(vec![
        ModelDeployment::new("opt-1.3b").with_slo(1.0).with_rate_share(4.0),
        ModelDeployment::new("opt-1.3b").with_slo(1.0).with_rate_share(3.0),
        ModelDeployment::new("opt-2.7b").with_slo(1.0).with_rate_share(2.0),
        ModelDeployment::new("opt-6.7b").with_slo(1.0).with_rate_share(1.0),
    ])
}

fn cluster_cfg(g: usize) -> SystemConfig {
    let mut cfg = SystemConfig::hetero_experiment(fleet(), 2, 8);
    cfg.engine.scheduler = SchedulerKind::Shed;
    cfg.placement =
        Some(PlacementSpec::replicated(g, cfg.parallel, 4, RouterKind::LeastLoaded));
    cfg
}

/// Dedicated sibling of `cluster_cfg`: the same fleet split across `g`
/// groups with every model hosted exactly once (round-robin partition) —
/// the embarrassingly parallel case the bounded-lag executor fast-paths
/// (DESIGN.md §13).
fn dedicated_cfg(g: usize, exec: ExecMode) -> SystemConfig {
    let mut cfg = SystemConfig::hetero_experiment(fleet(), 2, 8);
    cfg.engine.scheduler = SchedulerKind::Shed;
    cfg.exec = exec;
    let groups = (0..g)
        .map(|i| GroupSpec::new(cfg.parallel, (i..4).step_by(g).collect()))
        .collect();
    cfg.placement = Some(PlacementSpec { router: RouterKind::RoundRobin, groups });
    cfg
}

fn lcg(state: &mut u64) -> u64 {
    *state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
    *state >> 33
}

/// Hold-one churn: `ops` rounds of pop + schedule against a queue kept at
/// `pending` in-flight events. Returns processed events per wall second.
fn queue_churn(backend: QueueBackend, pending: usize, ops: usize) -> f64 {
    let mut q: EventQueue<u64> = EventQueue::with_backend(backend);
    let mut rng: u64 = 0x9E37_79B9 ^ pending as u64;
    for i in 0..pending {
        let d = (lcg(&mut rng) % 2_000) as f64 * 1e-4;
        q.schedule_in(d, i as u64);
    }
    let t = Instant::now();
    for i in 0..ops {
        let (_, id) = q.pop().expect("steady-state churn never drains");
        black_box(id);
        let roll = lcg(&mut rng);
        let mut d = (roll % 2_000) as f64 * 1e-4;
        if roll % 7 == 0 {
            // Occasional far-horizon event, like prefetch timers.
            d += 50.0;
        }
        q.schedule_in(d, (pending + i) as u64);
    }
    ops as f64 / t.elapsed().as_secs_f64()
}

struct E2eCell {
    scenario: String,
    groups: usize,
    backend: &'static str,
    exec: &'static str,
    events: u64,
    wall_secs: f64,
    events_per_sec: f64,
    requests: usize,
    drops: usize,
    /// `VmHWM` growth across this cell's run. The high-water mark is
    /// monotone, so the before/after delta is exactly the portion of
    /// peak RSS first reached during this cell (zero once a later cell
    /// stays under an earlier cell's mark).
    rss_delta_bytes: u64,
}

/// One end-to-end cell: streaming aggregation on, so the run measures
/// the simulator core, not record retention. Returns the report plus
/// the cell's `VmHWM` growth.
fn run_cell(cfg: SystemConfig, scenario: &str, heap: bool, duration: f64) -> (SimReport, u64) {
    let rss_before = peak_rss_bytes().unwrap_or(0);
    let params = ScenarioParams {
        num_models: 4,
        duration,
        seed: SEED,
        rate_scale: OVERLOAD_RATE_SCALE,
        rate_shares: cfg.models.rate_shares(),
        ..ScenarioParams::default()
    };
    let gen = scenarios::by_name(scenario, &params).expect("scenario resolves");
    let arrivals = gen.generate();
    let start = gen.measure_start();
    let mut sys = SimCluster::new(cfg, Driver::Open(arrivals)).expect("config valid");
    if heap {
        sys.use_binary_heap_queue();
    }
    sys.preload_warm();
    sys.set_streaming(start);
    let report = sys.run();
    assert_eq!(report.violations, 0, "{scenario}: violations");
    assert_eq!(report.oom_events, 0, "{scenario}: OOM");
    let rss_after = peak_rss_bytes().unwrap_or(0);
    (report, rss_after.saturating_sub(rss_before))
}

fn cell_from_report(
    scenario: &str,
    g: usize,
    backend: &'static str,
    exec: &'static str,
    report: &SimReport,
    rss_delta_bytes: u64,
) -> E2eCell {
    E2eCell {
        scenario: scenario.to_string(),
        groups: g,
        backend,
        exec,
        events: report.events,
        wall_secs: report.wall_secs,
        events_per_sec: report.events as f64 / report.wall_secs.max(1e-9),
        requests: report.groups.iter().map(|gs| gs.requests).sum(),
        drops: report.groups.iter().map(|gs| gs.drops).sum(),
        rss_delta_bytes,
    }
}

fn run_e2e(scenario: &str, g: usize, heap: bool, duration: f64) -> E2eCell {
    let (report, rss_delta) = run_cell(cluster_cfg(g), scenario, heap, duration);
    let backend = if heap { "heap" } else { "calendar" };
    cell_from_report(scenario, g, backend, "sequential", &report, rss_delta)
}

/// The seq ≡ par bit-for-bit contract at bench scale (the test-suite
/// copy lives in `rust/tests/determinism.rs`).
fn assert_reports_identical(seq: &SimReport, par: &SimReport, tag: &str) {
    assert_eq!(seq.events, par.events, "{tag}: events diverge");
    assert_eq!(seq.sim_end.to_bits(), par.sim_end.to_bits(), "{tag}: sim_end diverges");
    assert_eq!(seq.streaming_counts, par.streaming_counts, "{tag}: measured counts diverge");
    assert_eq!(seq.streaming_latency, par.streaming_latency, "{tag}: latency summary diverges");
    assert_eq!(seq.groups.len(), par.groups.len(), "{tag}: group count diverges");
    for (s, p) in seq.groups.iter().zip(&par.groups) {
        assert_eq!(
            (s.requests, s.drops, s.swaps, s.events),
            (p.requests, p.drops, p.swaps, p.events),
            "{tag}: group {} accounting diverges",
            s.group
        );
    }
    assert_eq!(seq.h2d_bytes, par.h2d_bytes, "{tag}: H2D traffic diverges");
    assert_eq!(seq.mem_high_water, par.mem_high_water, "{tag}: memory high-water diverges");
}

/// Peak resident set size in bytes (`VmHWM`); `None` off Linux.
fn peak_rss_bytes() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            let kb: u64 = rest.trim().trim_end_matches("kB").trim().parse().ok()?;
            return Some(kb * 1024);
        }
    }
    None
}

fn cell_json(c: &E2eCell) -> Json {
    Json::from_pairs(vec![
        ("scenario", c.scenario.as_str().into()),
        ("groups", c.groups.into()),
        ("backend", c.backend.into()),
        ("exec", c.exec.into()),
        ("events", (c.events as usize).into()),
        ("wall_secs", c.wall_secs.into()),
        ("events_per_sec", c.events_per_sec.into()),
        ("requests", c.requests.into()),
        ("drops", c.drops.into()),
        ("rss_delta_bytes", (c.rss_delta_bytes as usize).into()),
    ])
}

fn main() {
    let fast = common::fast_mode();

    // 1. Queue churn: the backend A/B at increasing pending-set sizes.
    section("queue churn: calendar vs BinaryHeap");
    let pendings: &[usize] =
        if fast { &[10_000, 1_000_000] } else { &[10_000, 1_000_000, 10_000_000] };
    let ops = if fast { 400_000 } else { 2_000_000 };
    let mut churn_rows = Vec::new();
    let mut churn_json = Vec::new();
    let mut churn_speedup = 0.0;
    for &pending in pendings {
        let cal = queue_churn(QueueBackend::Calendar, pending, ops);
        let heap = queue_churn(QueueBackend::Heap, pending, ops);
        let speedup = cal / heap;
        churn_speedup = speedup; // largest pending set wins (last)
        churn_rows.push(vec![
            pending.to_string(),
            fmt_rate(cal),
            fmt_rate(heap),
            format!("{speedup:.2}x"),
        ]);
        for (backend, rate) in [("calendar", cal), ("heap", heap)] {
            churn_json.push(Json::from_pairs(vec![
                ("backend", backend.into()),
                ("pending", pending.into()),
                ("events_per_sec", rate.into()),
            ]));
        }
    }
    table(&["pending", "calendar", "heap", "speedup"], &churn_rows);

    // 2. End-to-end registry sweep, calendar backend, streaming on.
    section("end-to-end: scenario registry x G in {1, 4} (hetero overload)");
    let duration = if fast { 6.0 } else { 20.0 };
    let mut e2e_cells = Vec::new();
    let mut e2e_rows = Vec::new();
    for &scenario in scenarios::names() {
        for g in [1usize, 4] {
            let cell = run_e2e(scenario, g, false, duration);
            e2e_rows.push(vec![
                cell.scenario.clone(),
                cell.groups.to_string(),
                cell.events.to_string(),
                format!("{:.3}", cell.wall_secs),
                fmt_rate(cell.events_per_sec),
            ]);
            e2e_cells.push(cell);
        }
    }
    table(&["scenario", "G", "events", "wall s", "events/sec"], &e2e_rows);

    // 3. Whole-system A/B on the headline 4-group zipf overload cell.
    section("calendar vs heap: 4-group zipf overload");
    let cal = run_e2e("zipf", 4, false, duration);
    let heap = run_e2e("zipf", 4, true, duration);
    let e2e_speedup = cal.events_per_sec / heap.events_per_sec;
    table(
        &["backend", "events", "wall s", "events/sec"],
        &[
            vec![
                "calendar".into(),
                cal.events.to_string(),
                format!("{:.3}", cal.wall_secs),
                fmt_rate(cal.events_per_sec),
            ],
            vec![
                "heap".into(),
                heap.events.to_string(),
                format!("{:.3}", heap.wall_secs),
                fmt_rate(heap.events_per_sec),
            ],
        ],
    );
    println!("end-to-end speedup (zipf, G=4): {e2e_speedup:.2}x");

    // 4. Parallel executor vs sequential on dedicated placements: each
    //    model hosted by exactly one group, so the bounded-lag executor
    //    takes its fast path (DESIGN.md §13). The bit-equality oracle
    //    runs before the speedup is reported — a fast-but-wrong parallel
    //    run can never post a number.
    section("parallel vs sequential: zipf overload, dedicated placements, G in {2, 4}");
    let mut par_cells = Vec::new();
    let mut par_rows = Vec::new();
    let mut parallel_speedup_g2 = 0.0;
    let mut parallel_speedup_g4 = 0.0;
    for g in [2usize, 4] {
        let (seq_report, seq_rss) =
            run_cell(dedicated_cfg(g, ExecMode::Sequential), "zipf", false, duration);
        let (par_report, par_rss) =
            run_cell(dedicated_cfg(g, ExecMode::ParallelGroups), "zipf", false, duration);
        assert_reports_identical(&seq_report, &par_report, &format!("zipf dedicated G={g}"));
        let seq =
            cell_from_report("zipf-dedicated", g, "calendar", "sequential", &seq_report, seq_rss);
        let par =
            cell_from_report("zipf-dedicated", g, "calendar", "parallel", &par_report, par_rss);
        let speedup = par.events_per_sec / seq.events_per_sec.max(1e-9);
        if g == 2 {
            parallel_speedup_g2 = speedup;
        } else {
            parallel_speedup_g4 = speedup;
        }
        for cell in [&seq, &par] {
            par_rows.push(vec![
                cell.groups.to_string(),
                cell.exec.to_string(),
                cell.events.to_string(),
                format!("{:.3}", cell.wall_secs),
                fmt_rate(cell.events_per_sec),
                format!("{:.1} MiB", cell.rss_delta_bytes as f64 / (1024.0 * 1024.0)),
            ]);
        }
        println!("parallel speedup (zipf dedicated, G={g}): {speedup:.2}x, reports bit-identical");
        par_cells.push(seq);
        par_cells.push(par);
    }
    table(&["G", "exec", "events", "wall s", "events/sec", "RSS delta"], &par_rows);

    let rss = peak_rss_bytes();
    if let Some(b) = rss {
        println!("peak RSS: {:.1} MiB", b as f64 / (1024.0 * 1024.0));
    }

    let mut e2e_json: Vec<Json> = e2e_cells.iter().map(cell_json).collect();
    e2e_json.push(cell_json(&cal));
    e2e_json.push(cell_json(&heap));
    common::save_bench_json(
        "perf_simcore",
        Json::from_pairs(vec![
            ("bench", "perf_simcore".into()),
            ("fast", fast.into()),
            // Flipped to true the first time the artifact is regenerated
            // from a real run on the CI reference machine; the perf-smoke
            // diff treats an uncalibrated baseline as advisory.
            ("calibrated", true.into()),
            ("queue_churn", Json::Arr(churn_json)),
            ("queue_speedup_largest_pending", churn_speedup.into()),
            ("e2e", Json::Arr(e2e_json)),
            ("e2e_speedup_zipf_g4", e2e_speedup.into()),
            ("parallel", Json::Arr(par_cells.iter().map(cell_json).collect())),
            ("parallel_speedup_g2", parallel_speedup_g2.into()),
            ("parallel_speedup_g4", parallel_speedup_g4.into()),
            ("peak_rss_bytes", rss.map(|b| b as usize).unwrap_or(0).into()),
        ]),
    );
}
