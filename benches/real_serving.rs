//! Real-mode serving benchmark: the paper's experiments replayed on the
//! actual PJRT execution path with opt-test instances (requires
//! `make artifacts`; skips gracefully otherwise).
//!
//! Reports measured load-entry times (the real "swap" on this substrate),
//! end-to-end latency with/without swapping, and batched throughput.

#[path = "common.rs"]
mod common;

use computron::config::EngineConfig;
use computron::serving::{Computron, ServeConfig};
use computron::util::bench::{fmt_duration, fmt_rate, section, table};
use computron::util::json::Json;
use std::time::Instant;

fn main() {
    let dir = computron::runtime::manifest::default_dir();
    if !dir.join("manifest.json").exists() {
        println!("real_serving: artifacts not built, skipping (run `make artifacts`)");
        return;
    }

    section("Real-mode serving (opt-test on CPU PJRT)");
    let ids: Vec<i32> = (1..9).collect();

    // --- Worst-case swapping: 2 models, cap 1, alternating (cf. §5.1) ---
    let mut cfg = ServeConfig::new(&dir, "opt-test", 2, 1, 1);
    cfg.engine = EngineConfig { resident_cap: 1, max_batch_size: 8, ..Default::default() };
    let server = Computron::launch(cfg).expect("launch");
    // Warmup.
    server.submit(0, ids.clone()).wait().unwrap();
    let n = 20;
    let t0 = Instant::now();
    for i in 0..n {
        server.submit(i % 2, ids.clone()).wait().unwrap();
    }
    let swap_elapsed = t0.elapsed().as_secs_f64();
    let stats = server.stats();
    let mean_load = stats.mean_load_secs;
    server.shutdown();

    // --- No-swap baseline: same load, cap 2 (both resident) ---
    let mut cfg = ServeConfig::new(&dir, "opt-test", 2, 1, 1);
    cfg.engine = EngineConfig { resident_cap: 2, max_batch_size: 8, ..Default::default() };
    let server = Computron::launch(cfg).expect("launch");
    server.submit(0, ids.clone()).wait().unwrap();
    server.submit(1, ids.clone()).wait().unwrap();
    let t0 = Instant::now();
    for i in 0..n {
        server.submit(i % 2, ids.clone()).wait().unwrap();
    }
    let noswap_elapsed = t0.elapsed().as_secs_f64();
    let noswap_stats = server.stats();
    server.shutdown();

    // --- Batched throughput: 64 concurrent requests to one model ---
    let mut cfg = ServeConfig::new(&dir, "opt-test", 1, 1, 1);
    cfg.engine = EngineConfig { resident_cap: 1, max_batch_size: 8, ..Default::default() };
    let server = Computron::launch(cfg).expect("launch");
    server.submit(0, ids.clone()).wait().unwrap();
    let t0 = Instant::now();
    let futs: Vec<_> = (0..64).map(|_| server.submit(0, ids.clone())).collect();
    for f in futs {
        f.wait().unwrap();
    }
    let batch_elapsed = t0.elapsed().as_secs_f64();
    server.shutdown();

    table(
        &["metric", "value"],
        &vec![
            vec![
                "alternating swap latency/request".to_string(),
                fmt_duration(swap_elapsed / n as f64),
            ],
            vec!["mean load-entry transfer".to_string(), fmt_duration(mean_load)],
            vec![
                "no-swap latency/request".to_string(),
                fmt_duration(noswap_elapsed / n as f64),
            ],
            vec![
                "swap overhead per request".to_string(),
                fmt_duration((swap_elapsed - noswap_elapsed).max(0.0) / n as f64),
            ],
            vec![
                "batched throughput (64 reqs)".to_string(),
                fmt_rate(64.0 / batch_elapsed),
            ],
        ],
    );

    assert!(noswap_stats.errors.is_empty());
    assert!(
        swap_elapsed > noswap_elapsed,
        "swapping path must cost more than resident path"
    );
    println!("shape checks passed: real swap overhead visible and bounded");

    common::save_report(
        "real_serving",
        Json::from_pairs(vec![
            ("swap_per_request", (swap_elapsed / n as f64).into()),
            ("noswap_per_request", (noswap_elapsed / n as f64).into()),
            ("mean_load_secs", mean_load.into()),
            ("batched_rps", (64.0 / batch_elapsed).into()),
        ]),
    );
}
