//! Design ablation — Fig 2 / Fig 3 / Fig 4 (§3.2): async pipelined load
//! entries vs the synchronous baseline vs the broadcast strawman.
//!
//! Expected: async < sync on swap latency (cross-stage loading
//! parallelism + no head-of-line blocking behind unrelated loads);
//! broadcast is fast but VIOLATES load dependencies (counted), which is
//! exactly why the paper pipelines load entries instead.

#[path = "common.rs"]
mod common;

use computron::baselines;
use computron::config::SystemConfig;
use computron::sim::{Driver, SimSystem};
use computron::util::bench::{section, table};
use computron::util::json::Json;

fn main() {
    let fast = common::fast_mode();
    let total = if fast { 8 } else { common::SWAP_REQUESTS };
    section("Ablation: load-entry design (async pipelined vs sync vs broadcast), PP=4");

    let run = move |cfg: SystemConfig| {
        let mut sys = SimSystem::new(cfg, Driver::AlternatingBlocking {
            models: 2,
            input_len: 2,
            total,
        })
        .unwrap();
        sys.preload(&[1]);
        sys.run()
    };

    let base = SystemConfig::swap_experiment(1, 4);
    let async_r = run(base.clone());
    let sync_r = run(baselines::sync_load(base.clone()));

    // The broadcast violation shows under overlapping open-loop arrivals.
    let broadcast_cfg = baselines::broadcast_load(SystemConfig::swap_experiment(1, 4));
    let arrivals: Vec<computron::sim::Arrival> = (0..24)
        .map(|i| computron::sim::Arrival { at: i as f64 * 0.05, model: i % 2, input_len: 2 })
        .collect();
    let mut sys = SimSystem::new(broadcast_cfg, Driver::Open(arrivals)).unwrap();
    sys.preload(&[0]);
    let broadcast_r = sys.run();

    let rows = vec![
        vec![
            "async pipelined (Computron)".to_string(),
            common::fmt_s(common::mean_swap(&async_r)),
            async_r.violations.to_string(),
        ],
        vec![
            "sync pipelined (Fig 3)".to_string(),
            common::fmt_s(common::mean_swap(&sync_r)),
            sync_r.violations.to_string(),
        ],
        vec![
            "broadcast (Fig 2)".to_string(),
            common::fmt_s(common::mean_swap(&broadcast_r)),
            broadcast_r.violations.to_string(),
        ],
    ];
    table(&["design", "mean swap (s)", "dependency violations"], &rows);

    assert!(common::mean_swap(&sync_r) > common::mean_swap(&async_r) * 1.5);
    assert_eq!(async_r.violations, 0);
    assert_eq!(sync_r.violations, 0);
    assert!(broadcast_r.violations > 0, "broadcast must violate dependencies");
    println!("shape checks passed: async fastest among correct designs; broadcast incorrect");

    let payload = Json::from_pairs(vec![
        ("experiment", "ablation_load_design".into()),
        ("fast", fast.into()),
        ("async_mean_swap", common::mean_swap(&async_r).into()),
        ("sync_mean_swap", common::mean_swap(&sync_r).into()),
        ("broadcast_violations", broadcast_r.violations.into()),
    ]);
    common::save_report("ablation_load_design", payload.clone());
    common::save_bench_json("ablation_load_design", payload);
}
