//! Fig 7 — swapping latency for TP=2, PP=2 vs pure TP=4 / PP=4 (§5.1).
//!
//! Expected shape (paper): at the same world size (4 GPUs), the mixed
//! configuration undercuts both pure configurations and approaches the
//! ideal scaling target — mixing halves both the TP α-term and the PP
//! pipe-hop overheads.

#[path = "common.rs"]
mod common;

use computron::util::bench::{section, table};
use computron::util::json::Json;

fn main() {
    section("Fig 7: swapping latency at world size 4 — mixed vs pure parallelism");
    let configs = [(4usize, 1usize, "TP=4,PP=1"), (1, 4, "TP=1,PP=4"), (2, 2, "TP=2,PP=2")];
    let points: Vec<_> =
        configs.iter().map(|&(tp, pp, _)| common::swap_point(tp, pp, |c| c)).collect();

    let rows: Vec<Vec<String>> = configs
        .iter()
        .zip(&points)
        .map(|(&(_, _, label), p)| {
            vec![
                label.to_string(),
                common::fmt_s(p.mean_swap),
                common::fmt_s(p.ideal),
                format!("{:.2}x", p.mean_swap / p.ideal),
                common::fmt_s(p.mean_e2e),
            ]
        })
        .collect();
    table(&["config", "swap (s)", "ideal (s)", "vs ideal", "e2e (s)"], &rows);

    let (tp4, pp4, mixed) = (&points[0], &points[1], &points[2]);
    assert!(mixed.mean_swap < tp4.mean_swap, "mixed beats pure TP");
    assert!(mixed.mean_swap < pp4.mean_swap, "mixed beats pure PP");
    assert!(
        mixed.mean_swap / mixed.ideal < 1.8,
        "mixed approaches the ideal target ({}x)",
        mixed.mean_swap / mixed.ideal
    );
    println!("shape checks passed: mixed < pure TP, mixed < pure PP, near ideal");

    common::save_report(
        "fig7_swap_mixed",
        Json::from_pairs(vec![
            ("figure", "fig7".into()),
            ("points", Json::Arr(points.iter().map(|p| p.to_json()).collect())),
        ]),
    );
}
