//! Resilience suite (DESIGN.md §11): the goodput-dip and recovery-time
//! oracles for fault injection and self-healing routing.
//!
//! One steady overload stream is played against three fleets:
//!
//! - `replicated`: 2 groups, every model on both, with a retry budget —
//!   the self-healing configuration;
//! - `partitioned`: 2 groups, disjoint model shards (no replication),
//!   same retry budget — the ablation;
//! - `no-fault`: the replicated fleet with no fault plan — the baseline
//!   that pins the fault layer's zero-cost contract.
//!
//! Mid-window, group 1 takes a hard failure and recovers 20% of the
//! window later. Goodput (completions/s by completion time) is measured
//! in three windows: pre-failure, during the outage (dip), and
//! post-recovery. The offered rate is self-calibrated to 70% of one
//! group's measured burst throughput, so a single surviving replica can
//! absorb the re-homed stream (zero loss) while an unreplicated shard
//! structurally cannot — the oracles hold by construction, not by a
//! hand-tuned constant.
//!
//! Oracles asserted on every run:
//!
//! - replication + health-aware routing + retries lose **zero** requests
//!   across the outage, and post-recovery goodput is >= 90% of
//!   pre-failure goodput;
//! - without replication the same fault loses requests (all recorded as
//!   `DropReason::Fault`) and the goodput dip is strictly deeper;
//! - the recovery-time metric equals the injected fail->recover gap;
//! - event conservation holds: per-group events + dead-event drops +
//!   cluster events == total processed events;
//! - the no-fault baseline reports all-zero fault stats.
//!
//! ```bash
//! cargo bench --bench resilience_suite              # full window
//! cargo bench --bench resilience_suite -- --fast    # CI smoke window
//! ```

#[path = "common.rs"]
mod common;

use computron::cluster::fault::{FaultEvent, FaultKind, FaultPlan, RetryPolicy};
use computron::config::{GroupSpec, PlacementSpec, RouterKind, SystemConfig};
use computron::coordinator::DropReason;
use computron::sim::{Arrival, Driver, FaultStats, SimCluster, SimReport};
use computron::util::bench::{section, table};
use computron::util::json::Json;

const NUM_MODELS: usize = 3;

fn base_cfg() -> SystemConfig {
    SystemConfig::workload_experiment(NUM_MODELS, 2, 8)
}

fn replicated_placement(cfg: &SystemConfig) -> PlacementSpec {
    PlacementSpec::replicated(2, cfg.parallel, NUM_MODELS, RouterKind::LeastLoaded)
}

/// Disjoint shards: group 0 hosts models {0,1}, group 1 hosts {2} — no
/// model survives its group.
fn partitioned_placement(cfg: &SystemConfig) -> PlacementSpec {
    PlacementSpec {
        router: RouterKind::LeastLoaded,
        groups: vec![
            GroupSpec::new(cfg.parallel, vec![0, 1]),
            GroupSpec::new(cfg.parallel, vec![2]),
        ],
    }
}

fn outage_plan(fail_at: f64, recover_at: f64) -> FaultPlan {
    FaultPlan {
        events: vec![
            FaultEvent { at: fail_at, kind: FaultKind::GroupFail { group: 1 } },
            FaultEvent { at: recover_at, kind: FaultKind::GroupRecover { group: 1 } },
        ],
        retry: RetryPolicy { max_retries: 3, backoff: 0.05 },
        autoscale: None,
    }
}

fn steady_arrivals(n: usize, rate: f64) -> Vec<Arrival> {
    (0..n)
        .map(|i| Arrival { at: i as f64 / rate, model: i % NUM_MODELS, input_len: 8 })
        .collect()
}

/// Burst throughput of one group serving the full catalog (req/s):
/// everything arrives at t = 0 and the makespan is measured. The suite
/// offers 70% of this, so a lone group stays under capacity.
fn calibrate_single_group_rate() -> f64 {
    let mut cfg = base_cfg();
    cfg.placement = Some(PlacementSpec::replicated(
        1,
        cfg.parallel,
        NUM_MODELS,
        RouterKind::LeastLoaded,
    ));
    let n = 60usize;
    let burst: Vec<Arrival> =
        (0..n).map(|i| Arrival { at: 0.0, model: i % NUM_MODELS, input_len: 8 }).collect();
    let mut sys = SimCluster::new(cfg, Driver::Open(burst)).expect("config");
    sys.preload_warm();
    let report = sys.run();
    assert_eq!(report.requests.len(), n, "calibration burst must fully complete");
    let makespan = report.requests.iter().map(|r| r.done).fold(0.0_f64, f64::max);
    assert!(makespan > 0.0, "calibration makespan must be positive");
    n as f64 / makespan
}

fn run_fleet(
    placement: PlacementSpec,
    faults: Option<FaultPlan>,
    n: usize,
    rate: f64,
) -> SimReport {
    let mut cfg = base_cfg();
    cfg.placement = Some(placement);
    cfg.faults = faults;
    let mut sys =
        SimCluster::new(cfg, Driver::Open(steady_arrivals(n, rate))).expect("config");
    sys.preload_warm();
    sys.run()
}

/// Completions per second, by completion time, inside `[lo, hi)`.
fn goodput(report: &SimReport, lo: f64, hi: f64) -> f64 {
    let done = report.requests.iter().filter(|r| r.done >= lo && r.done < hi).count();
    done as f64 / (hi - lo)
}

fn conservation_holds(report: &SimReport) -> bool {
    report.groups.iter().map(|g| g.events).sum::<u64>()
        + report.fault_stats.dead_event_drops
        + report.fault_stats.cluster_events
        == report.events
}

struct Outcome {
    name: &'static str,
    pre: f64,
    dip: f64,
    post: f64,
    dip_depth: f64,
    lost: u64,
    retried: u64,
    rehomed: u64,
    recovery_time: f64,
}

impl Outcome {
    fn row(&self) -> Vec<String> {
        vec![
            self.name.to_string(),
            common::fmt_s(self.pre),
            common::fmt_s(self.dip),
            common::fmt_s(self.post),
            format!("{:.1}%", 100.0 * self.dip_depth),
            self.lost.to_string(),
            self.retried.to_string(),
            self.rehomed.to_string(),
            common::fmt_s(self.recovery_time),
        ]
    }

    fn json(&self) -> Json {
        Json::from_pairs(vec![
            ("fleet", self.name.into()),
            ("pre_goodput", self.pre.into()),
            ("dip_goodput", self.dip.into()),
            ("post_goodput", self.post.into()),
            ("dip_depth", self.dip_depth.into()),
            ("lost", (self.lost as f64).into()),
            ("retried", (self.retried as f64).into()),
            ("rehomed", (self.rehomed as f64).into()),
            ("recovery_time", self.recovery_time.into()),
        ])
    }
}

fn measure(
    name: &'static str,
    report: &SimReport,
    fail_at: f64,
    recover_at: f64,
    d: f64,
) -> Outcome {
    // Pre skips warm-up; post skips a short drain margin after recovery.
    let pre = goodput(report, 0.1 * d, fail_at);
    let dip = goodput(report, fail_at, recover_at);
    let post = goodput(report, recover_at + 0.05 * d, 0.95 * d);
    Outcome {
        name,
        pre,
        dip,
        post,
        dip_depth: if pre > 0.0 { 1.0 - dip / pre } else { 0.0 },
        lost: report.fault_stats.lost,
        retried: report.fault_stats.retried,
        rehomed: report.fault_stats.rehomed,
        recovery_time: report.groups[1].recovery_time,
    }
}

fn main() {
    let fast = common::fast_mode();
    let total = if fast { 320usize } else { 800 };
    let single_rate = calibrate_single_group_rate();
    let rate = 0.7 * single_rate;
    let duration = total as f64 / rate;
    let fail_at = 0.4 * duration;
    let recover_at = 0.6 * duration;

    section(&format!(
        "Resilience suite: {rate:.2} req/s (70% of one group's {single_rate:.2}) x \
         {duration:.1} s, group 1 fails at {fail_at:.1} s, recovers at {recover_at:.1} s"
    ));

    let base = base_cfg();
    let repl = run_fleet(
        replicated_placement(&base),
        Some(outage_plan(fail_at, recover_at)),
        total,
        rate,
    );
    let part = run_fleet(
        partitioned_placement(&base),
        Some(outage_plan(fail_at, recover_at)),
        total,
        rate,
    );
    let calm = run_fleet(replicated_placement(&base), None, total, rate);

    for (tag, r) in [("replicated", &repl), ("partitioned", &part), ("no-fault", &calm)] {
        assert!(conservation_holds(r), "{tag}: event conservation violated");
        assert_eq!(r.violations, 0, "{tag}: dependency violations");
        assert_eq!(r.oom_events, 0, "{tag}: OOM events");
        assert_eq!(
            r.requests.len() + r.drops.len(),
            total,
            "{tag}: completions + drops must cover every arrival"
        );
    }

    let o_repl = measure("replicated", &repl, fail_at, recover_at, duration);
    let o_part = measure("partitioned", &part, fail_at, recover_at, duration);
    let o_calm = measure("no-fault", &calm, fail_at, recover_at, duration);

    // --- oracle 1: self-healing fleet loses nothing and recovers ---
    assert_eq!(o_repl.lost, 0, "replication + retries must lose zero requests");
    assert_eq!(repl.requests.len(), total, "every arrival completes on the replicated fleet");
    assert!(
        o_repl.post >= 0.9 * o_repl.pre,
        "post-recovery goodput {:.3} must reach 90% of pre-failure {:.3}",
        o_repl.post,
        o_repl.pre
    );

    // --- oracle 2: without replication the dip is strictly deeper ---
    assert!(o_part.lost > 0, "the unreplicated shard must lose its model's requests");
    assert!(
        part.drops.iter().all(|d| d.reason == DropReason::Fault),
        "partitioned losses are fault drops"
    );
    assert!(
        o_part.dip_depth > o_repl.dip_depth,
        "unreplicated dip {:.3} must be strictly deeper than replicated {:.3}",
        o_part.dip_depth,
        o_repl.dip_depth
    );

    // --- oracle 3: recovery-time metric equals the injected gap ---
    for (tag, r) in [("replicated", &repl), ("partitioned", &part)] {
        assert_eq!(r.groups[1].failures, 1, "{tag}: one injected failure");
        assert!(
            (r.groups[1].recovery_time - (recover_at - fail_at)).abs() < 1e-9,
            "{tag}: recovery time {} != injected gap {}",
            r.groups[1].recovery_time,
            recover_at - fail_at
        );
        assert_eq!(r.groups[1].downtime, r.groups[1].recovery_time, "{tag}: closed outage");
    }

    // --- oracle 4: the fault layer is free when unused ---
    assert_eq!(calm.fault_stats, FaultStats::default(), "no-fault run must report zero stats");
    assert_eq!(calm.requests.len(), total, "no-fault run completes everything");

    table(
        &[
            "fleet",
            "pre (req/s)",
            "dip (req/s)",
            "post (req/s)",
            "dip depth",
            "lost",
            "retried",
            "re-homed",
            "recovery (s)",
        ],
        &[o_repl.row(), o_part.row(), o_calm.row()],
    );
    println!(
        "\noracles held: zero loss + >=90% recovery under replication, strictly deeper dip \
         without it, recovery time == injected outage, no-fault identity"
    );

    let payload = Json::from_pairs(vec![
        ("experiment", "resilience_suite".into()),
        ("duration", duration.into()),
        ("rate", rate.into()),
        ("single_group_rate", single_rate.into()),
        ("fail_at", fail_at.into()),
        ("recover_at", recover_at.into()),
        ("fast", fast.into()),
        ("fleets", Json::Arr(vec![o_repl.json(), o_part.json(), o_calm.json()])),
    ]);
    common::save_report("resilience_suite", payload.clone());
    common::save_bench_json("resilience_suite", payload);
}
