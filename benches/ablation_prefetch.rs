//! Speculative-prefetch ablation — the paper's §6 future-work hypothesis:
//! "more sophisticated load scheduling algorithms with predictive
//! capabilities can drastically reduce the number of on-demand swaps, and
//! by extension, serving latency."
//!
//! Workload: 3 models requested in a fixed cyclic order (one of the §6
//! example patterns) with residency cap 2, so plain LRU evicts exactly
//! the model needed next — the pathological case. The Markov prefetcher
//! learns the cycle and loads the next model into the free slot while the
//! current batch executes.

#[path = "common.rs"]
mod common;

use computron::config::SystemConfig;
use computron::sim::{Driver, SimSystem};
use computron::util::bench::{section, table};
use computron::util::json::Json;

fn run(prefetch: bool, total: usize) -> (f64, u64) {
    let mut cfg = SystemConfig::workload_experiment(3, 2, 8);
    cfg.engine.prefetch = prefetch;
    let mut sys = SimSystem::new(cfg, Driver::AlternatingBlocking {
        models: 3,
        input_len: 8,
        total,
    })
    .unwrap();
    sys.preload(&[0]);
    let r = sys.run();
    let mean = r.requests.iter().map(|q| q.latency()).sum::<f64>() / r.requests.len() as f64;
    (mean, r.swap_stats.loads_completed)
}

fn main() {
    let fast = common::fast_mode();
    let total = if fast { 18 } else { 30 };
    section("Ablation: speculative prefetch (§6 extension), cyclic 3-model load, cap 2");
    let (base_mean, base_loads) = run(false, total);
    let (pf_mean, pf_loads) = run(true, total);

    table(
        &["variant", "mean latency (s)", "loads"],
        &vec![
            vec!["on-demand only (paper)".into(), common::fmt_s(base_mean), base_loads.to_string()],
            vec!["markov prefetch".into(), common::fmt_s(pf_mean), pf_loads.to_string()],
            vec![
                "improvement".into(),
                format!("{:.2}x", base_mean / pf_mean),
                String::new(),
            ],
        ],
    );

    assert!(
        pf_mean < base_mean * 0.8,
        "prefetch must cut latency on predictable patterns: {base_mean} -> {pf_mean}"
    );
    println!("shape checks passed: predictive loading hides on-demand swaps (paper §6 hypothesis)");

    let payload = Json::from_pairs(vec![
        ("experiment", "ablation_prefetch".into()),
        ("fast", fast.into()),
        ("baseline_mean", base_mean.into()),
        ("prefetch_mean", pf_mean.into()),
    ]);
    common::save_report("ablation_prefetch", payload.clone());
    common::save_bench_json("ablation_prefetch", payload);
}
