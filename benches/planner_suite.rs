//! Planner suite — the placement planner's headline oracle (DESIGN.md
//! §10): on the skewed hetero fleet (`configs/hetero_4model.json`, 4:3:2:1
//! shares, 0.8–4 s SLOs) under zipf and flash-crowd overload, the plan
//! found by `computron plan` with an 8-GPU budget must **strictly beat**
//! every hand-written preset and every single-group baseline on goodput:
//!
//! - `hetero_4model` itself (the legacy G=1 tp2×pp2 layout, 4 GPUs);
//! - the `groups_2x2` preset's placement (2 × tp2×pp2 replicated groups,
//!   resident-affinity routing, 8 GPUs) applied to the same fleet;
//! - the G=1 8-GPU scale-up (one tp2×pp4 group hosting everything).
//!
//! All candidates — the planner's output and every baseline — are scored
//! by one shared `sim::EvalHarness` trace per cell, so the comparison is
//! free of workload sampling noise. Further oracles on every cell:
//!
//! - the annealer never returns worse than its greedy seed;
//! - the planner spends at most its evaluation budget;
//! - the winning spec partitions exactly the 8-GPU budget;
//! - re-evaluating the winning spec on the bench's own harness
//!   reproduces the planner's reported outcome bit-for-bit (the
//!   determinism contract, at full bench scale);
//! - the zipf cell re-planned with a 1-worker and a 4-worker scoring
//!   pool returns the identical plan bit-for-bit (batch-synchronous
//!   scoring, DESIGN.md §13), and the candidates/sec of both arms —
//!   plus their ratio — land in the JSON artifact.
//!
//! ```bash
//! cargo bench --bench planner_suite              # full sweep
//! cargo bench --bench planner_suite -- --fast    # CI smoke subset
//! ```

#[path = "common.rs"]
mod common;

use std::time::Instant;

use computron::config::{ParallelConfig, PlacementSpec, PlannerConfig, SystemConfig};
use computron::coordinator::planner;
use computron::sim::EvalHarness;
use computron::util::bench::{section, table};
use computron::util::json::Json;

const SEED: u64 = 0x914A_C0DE;
const GPU_BUDGET: usize = 8;

fn preset(name: &str) -> SystemConfig {
    let path = format!("configs/{name}.json");
    SystemConfig::from_file(std::path::Path::new(&path))
        .unwrap_or_else(|e| panic!("preset {path} must load: {e}"))
}

/// The three hand-written baselines the planner must beat, labelled.
fn baselines(base: &SystemConfig) -> Vec<(&'static str, PlacementSpec)> {
    vec![
        // The fleet's own legacy layout: one tp2 x pp2 group, 4 GPUs.
        ("hetero_4model G=1 tp2pp2", base.resolved_placement()),
        // The checked-in 2-group preset's placement on the same fleet.
        ("groups_2x2 preset", preset("groups_2x2").resolved_placement()),
        // Single-group scale-up to the full budget: one tp2 x pp4 group.
        (
            "single 8-GPU tp2pp4",
            PlacementSpec::single(ParallelConfig::new(2, 4), base.num_models()),
        ),
    ]
}

fn main() {
    let fast = common::fast_mode();
    let duration = if fast { 6.0 } else { 20.0 };
    let eval_budget = if fast { 24 } else { 64 };
    // Offered load far above single-group capacity (matches the
    // group_scaling overload cells): planning matters when capacity-bound.
    let cells: &[(&str, f64)] = if fast {
        &[("zipf", 60.0)]
    } else {
        &[("zipf", 60.0), ("flash-crowd", 32.0)]
    };

    let base = preset("hetero_4model");
    section(&format!(
        "Planner suite: {} catalog, {GPU_BUDGET}-GPU budget, {} cells x {duration} s, {eval_budget} evals",
        "hetero_4model",
        cells.len()
    ));

    let mut rows: Vec<Vec<String>> = Vec::new();
    let mut cells_json: Vec<Json> = Vec::new();
    for &(scenario, rate_scale) in cells {
        let mut knobs = PlannerConfig::for_config(&base, GPU_BUDGET);
        knobs.duration = duration;
        knobs.rate_scale = rate_scale;
        knobs.eval_budget = eval_budget;
        knobs.seed = SEED;

        let plan = planner::plan(&base, scenario, &knobs)
            .unwrap_or_else(|e| panic!("{scenario}: planner failed: {e}"));
        let tag = format!("{scenario}@x{rate_scale}");
        assert!(
            plan.score >= plan.greedy_score,
            "{tag}: annealer returned worse than its greedy seed ({} < {})",
            plan.score,
            plan.greedy_score
        );
        assert!(
            plan.evals <= eval_budget,
            "{tag}: spent {} evals over the {eval_budget} budget",
            plan.evals
        );
        assert_eq!(
            plan.spec.world(),
            GPU_BUDGET,
            "{tag}: plan must partition exactly the GPU budget"
        );

        // Score the plan and every baseline on one shared trace.
        let harness = EvalHarness::new(base.clone(), scenario, duration, SEED, rate_scale)
            .expect("scenario resolves");
        let planned = harness.evaluate(&plan.spec).expect("plan spec evaluates");
        assert_eq!(
            planned, plan.outcome,
            "{tag}: re-evaluating the plan must reproduce the planner's outcome bit-for-bit"
        );

        let mut cell_rows = vec![("planner".to_string(), plan.spec.groups.len(), planned)];
        for (label, spec) in baselines(&base) {
            let outcome = harness
                .evaluate(&spec)
                .unwrap_or_else(|e| panic!("{tag}: baseline {label} must evaluate: {e}"));
            assert!(
                planned.goodput > outcome.goodput,
                "{tag}: planner goodput {:.2} does not strictly beat {label} ({:.2})",
                planned.goodput,
                outcome.goodput
            );
            cell_rows.push((label.to_string(), spec.groups.len(), outcome));
        }

        let mut outcomes_json = Vec::new();
        for (label, groups, o) in &cell_rows {
            rows.push(vec![
                scenario.to_string(),
                label.clone(),
                groups.to_string(),
                format!("{:.2}", o.goodput),
                format!("{:.1}%", 100.0 * o.attainment),
                format!("{:.3}", o.p99),
                o.drops.to_string(),
            ]);
            outcomes_json.push(Json::from_pairs(vec![
                ("candidate", label.as_str().into()),
                ("groups", (*groups).into()),
                ("goodput", o.goodput.into()),
                ("attainment", o.attainment.into()),
                ("p99", o.p99.into()),
                ("completed", o.completed.into()),
                ("attained", o.attained.into()),
                ("drops", o.drops.into()),
            ]));
        }
        println!(
            "{tag}: planner ({} groups, {} evals over {} candidates) strictly beats all {} baselines",
            plan.spec.groups.len(),
            plan.evals,
            plan.enumerated,
            cell_rows.len() - 1
        );
        cells_json.push(Json::from_pairs(vec![
            ("scenario", scenario.into()),
            ("rate_scale", rate_scale.into()),
            ("evals", plan.evals.into()),
            ("enumerated", plan.enumerated.into()),
            ("greedy_score", plan.greedy_score.into()),
            ("score", plan.score.into()),
            ("plan", plan.spec.to_json()),
            ("outcomes", Json::Arr(outcomes_json)),
        ]));
    }

    table(
        &["scenario", "candidate", "groups", "goodput (req/s)", "attainment", "p99 (s)", "drops"],
        &rows,
    );
    println!(
        "\noracles held on every cell: annealer >= greedy seed, budget respected, \
         exact budget partition, bit-for-bit re-evaluation, and strict goodput wins \
         over every hand-written and single-group baseline"
    );

    // Parallel-scoring A/B: the zipf cell planned with a 1-worker and a
    // 4-worker scoring pool. The plan is worker-count independent by
    // construction (batch-synchronous scoring, DESIGN.md §13) — the
    // identity is asserted before the speedup is reported, so a
    // fast-but-divergent pool can never post a number.
    section("planner scoring pool: workers 1 vs 4 (zipf cell)");
    let mut ab_knobs = PlannerConfig::for_config(&base, GPU_BUDGET);
    ab_knobs.duration = duration;
    ab_knobs.rate_scale = 60.0;
    ab_knobs.eval_budget = eval_budget;
    ab_knobs.seed = SEED;
    let mut workers_json = Vec::new();
    let mut rates = [0.0_f64; 2];
    let mut plans = Vec::new();
    for (slot, workers) in [1usize, 4].into_iter().enumerate() {
        ab_knobs.workers = workers;
        let t = Instant::now();
        let plan = planner::plan(&base, "zipf", &ab_knobs)
            .unwrap_or_else(|e| panic!("workers={workers}: planner failed: {e}"));
        let wall = t.elapsed().as_secs_f64();
        let rate = plan.evals as f64 / wall.max(1e-9);
        rates[slot] = rate;
        println!(
            "workers={workers}: {} evals in {wall:.3} s ({rate:.1} candidates/sec)",
            plan.evals
        );
        workers_json.push(Json::from_pairs(vec![
            ("workers", workers.into()),
            ("evals", plan.evals.into()),
            ("wall_secs", wall.into()),
            ("candidates_per_sec", rate.into()),
        ]));
        plans.push(plan);
    }
    assert_eq!(
        plans[0].spec, plans[1].spec,
        "scoring pool width must not change the plan"
    );
    assert_eq!(
        plans[0].score.to_bits(),
        plans[1].score.to_bits(),
        "scoring pool width must not change the plan score"
    );
    assert_eq!(
        plans[0].evals, plans[1].evals,
        "scoring pool width must not change the eval count"
    );
    assert_eq!(
        plans[0].outcome, plans[1].outcome,
        "scoring pool width must not change the winning outcome"
    );
    let planner_speedup_workers4 = rates[1] / rates[0].max(1e-9);
    println!("planner scoring speedup (workers=4 vs 1): {planner_speedup_workers4:.2}x");

    let payload = Json::from_pairs(vec![
        ("experiment", "planner_suite".into()),
        ("duration", duration.into()),
        ("eval_budget", eval_budget.into()),
        ("gpu_budget", GPU_BUDGET.into()),
        ("seed", SEED.into()),
        ("fast", fast.into()),
        ("cells", Json::Arr(cells_json)),
        ("scoring_workers", Json::Arr(workers_json)),
        ("planner_speedup_workers4", planner_speedup_workers4.into()),
    ]);
    common::save_report("planner_suite", payload.clone());
    common::save_bench_json("planner_suite", payload);
}
