//! Shared experiment drivers for the paper-figure benches.
//!
//! Every bench prints the same rows/series the paper reports and writes a
//! JSON report under `reports/` for plotting. Runs are deterministic
//! (seeded), so figures regenerate bit-identically.
#![allow(dead_code)] // each bench binary uses a subset of these helpers

use computron::config::SystemConfig;
use computron::coordinator::engine::SwapRecord;
use computron::metrics::{SwapScalingPoint, WorkloadCell};
use computron::sim::{Driver, SimReport, SimSystem};
use computron::workload::GammaWorkload;

/// Number of alternating blocking requests in §5.1-style experiments.
pub const SWAP_REQUESTS: usize = 20;

/// Run the §5.1 worst-case swap experiment for one configuration.
pub fn run_swap_experiment(cfg: SystemConfig) -> SimReport {
    let mut sys = SimSystem::new(cfg, Driver::AlternatingBlocking {
        models: 2,
        input_len: 2,
        total: SWAP_REQUESTS,
    })
    .expect("config valid");
    sys.preload(&[1]);
    sys.run()
}

/// §5.1 scaling point for (tp, pp) under a given config transform.
pub fn swap_point(
    tp: usize,
    pp: usize,
    transform: impl Fn(SystemConfig) -> SystemConfig,
) -> SwapScalingPoint {
    let cfg = transform(SystemConfig::swap_experiment(tp, pp));
    let link_bw = cfg.hardware.link.bandwidth;
    let model_bytes = cfg.spec().unwrap().param_bytes();
    let report = run_swap_experiment(cfg);
    SwapScalingPoint::from_records(
        tp,
        pp,
        &report.swaps,
        &report.requests,
        model_bytes,
        link_bw,
    )
}

/// Run one §5.2 workload cell (skew row × CV column).
pub fn run_workload_cell(
    num_models: usize,
    cap: usize,
    max_batch: usize,
    rates: &[f64],
    cv: f64,
    seed: u64,
) -> WorkloadCell {
    run_workload_cell_with(num_models, cap, max_batch, rates, cv, seed, |c| c)
}

/// `run_workload_cell` with a config transform (e.g. switch the load
/// design) applied before the run.
pub fn run_workload_cell_with(
    num_models: usize,
    cap: usize,
    max_batch: usize,
    rates: &[f64],
    cv: f64,
    seed: u64,
    transform: impl Fn(SystemConfig) -> SystemConfig,
) -> WorkloadCell {
    let cfg = transform(SystemConfig::workload_experiment(num_models, cap, max_batch));
    let workload = GammaWorkload::new(rates.to_vec(), cv, seed);
    let arrivals = workload.generate();
    let measure_start = workload.measure_start();
    let duration = workload.duration;
    let mut sys = SimSystem::new(cfg, Driver::Open(arrivals)).expect("config valid");
    // Paper warms up before measuring; start with the first `cap` models
    // resident, as a warm server would be.
    let preload: Vec<usize> = (0..cap.min(num_models)).collect();
    sys.preload(&preload);
    let report = sys.run();
    assert_eq!(report.violations, 0, "pipelined designs never violate dependencies");
    assert_eq!(report.oom_events, 0);
    WorkloadCell::from_report(
        &computron::workload::gamma::paper::skew_label(rates),
        cv,
        &report,
        measure_start,
        duration,
    )
}

/// Mean swap duration of a report.
pub fn mean_swap(report: &SimReport) -> f64 {
    if report.swaps.is_empty() {
        return 0.0;
    }
    report.swaps.iter().map(SwapRecord::duration).sum::<f64>() / report.swaps.len() as f64
}

/// Write a JSON report under `reports/`.
pub fn save_report(name: &str, json: computron::util::json::Json) {
    let dir = std::path::Path::new("reports");
    std::fs::create_dir_all(dir).expect("mkdir reports");
    let path = dir.join(format!("{name}.json"));
    std::fs::write(&path, json.pretty()).expect("write report");
    println!("[report] wrote {}", path.display());
}

/// Destination of the machine-readable bench summary: `--json <path>`
/// (after `cargo bench --bench <name> --`) when given, else
/// `BENCH_<name>.json` in the working directory. These files are the
/// cross-PR perf trajectory; CI uploads them as artifacts.
pub fn bench_json_path(name: &str) -> std::path::PathBuf {
    let args: Vec<String> = std::env::args().collect();
    for pair in args.windows(2) {
        if pair[0] == "--json" {
            return std::path::PathBuf::from(&pair[1]);
        }
    }
    std::path::PathBuf::from(format!("BENCH_{name}.json"))
}

/// Write the machine-readable `BENCH_<name>.json` summary.
pub fn save_bench_json(name: &str, json: computron::util::json::Json) {
    let path = bench_json_path(name);
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir).expect("mkdir bench json dir");
        }
    }
    std::fs::write(&path, json.pretty()).expect("write bench json");
    println!("[bench-json] wrote {}", path.display());
}

/// `--fast` (after `--`): trim workloads for CI smoke runs.
pub fn fast_mode() -> bool {
    std::env::args().any(|a| a == "--fast")
}

/// Format seconds for table cells.
pub fn fmt_s(x: f64) -> String {
    format!("{x:.3}")
}
