//! §Perf micro-benchmarks — the L3 hot paths (criterion-style harness
//! from util::bench since criterion is unavailable offline).
//!
//! Targets (EXPERIMENTS.md §Perf): engine scheduling decision < 10 µs;
//! DES throughput > 1M events/s; collective round-trip and JSON parse
//! tracked for regressions.
//!
//! `cargo bench --bench perf_hotpath -- --fast` trims warmup/measure
//! budgets and the sim workload for the CI perf smoke job; either way a
//! machine-readable `BENCH_perf_hotpath.json` (or `--json <path>`)
//! records the summaries so the perf trajectory is tracked across PRs.

#[path = "common.rs"]
mod common;

use computron::config::{EngineConfig, LoadDesign, SystemConfig};
use computron::coordinator::engine::Engine;
use computron::sim::{Driver, SimSystem};
use computron::util::bench::{black_box, fmt_rate, section, Bencher};
use computron::util::json::Json;

fn main() {
    let fast = common::fast_mode();
    section(if fast { "Perf: L3 hot paths (fast mode)" } else { "Perf: L3 hot paths" });
    let mut b = if fast { Bencher::fast() } else { Bencher::default() };

    // Engine request->dispatch round trip (resident model, no swap).
    b.bench("engine: on_request + drain (hot, resident)", {
        let mut e = Engine::new(4, 4, 2, EngineConfig::default(), 1);
        e.force_resident(0, 0.0);
        let mut now = 0.0;
        let mut pending: Vec<u64> = Vec::new();
        move || {
            now += 0.001;
            e.on_request(now, 0, 8);
            for entry in e.drain_outbox() {
                if let computron::coordinator::Entry::Batch(bb) = entry {
                    pending.push(bb.id);
                }
            }
            // Complete eagerly so state stays bounded.
            while pending.len() > 2 {
                let id = pending.remove(0);
                e.on_batch_done(now, id);
                for entry in e.drain_outbox() {
                    if let computron::coordinator::Entry::Batch(bb) = entry {
                        pending.push(bb.id);
                    }
                }
            }
            e.take_completed();
        }
    });

    // Swap decision (plan + victim selection) under cap pressure.
    b.bench("engine: swap decision (cap pressure)", {
        let mut e = Engine::new(8, 1, 1, EngineConfig { resident_cap: 2, ..Default::default() }, 2);
        e.force_resident(0, 0.0);
        e.force_resident(1, 0.0);
        let mut now = 0.0;
        let mut model = 2usize;
        move || {
            now += 0.01;
            e.on_request(now, model, 8);
            // Resolve the swap immediately.
            let out = e.drain_outbox();
            for entry in &out {
                if entry.is_load() {
                    e.on_load_ack(now, entry.id());
                }
            }
            for entry in e.drain_outbox() {
                if let computron::coordinator::Entry::Batch(bb) = entry {
                    e.on_batch_done(now, bb.id);
                }
            }
            e.take_completed();
            model = 2 + (model - 1) % 6;
        }
    });

    // Whole-simulation throughput: events/sec on a Tab-1 style cell, for
    // both the monolithic async design and the chunked swap pipeline
    // (the chunked inner loop carries extra chunk events — regressions in
    // either show up here).
    let mut sim_stats: Vec<Json> = Vec::new();
    for design in [LoadDesign::AsyncPipelined, LoadDesign::ChunkedPipelined] {
        let mut cfg = SystemConfig::workload_experiment(3, 2, 8);
        cfg.engine.load_design = design;
        let rate = if fast { 3.0 } else { 10.0 };
        let workload = computron::workload::GammaWorkload::new(vec![rate, rate, rate], 1.0, 7);
        let arrivals = workload.generate();
        let t0 = std::time::Instant::now();
        let mut sys = SimSystem::new(cfg, Driver::Open(arrivals)).unwrap();
        sys.preload(&[0, 1]);
        let report = sys.run();
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "sim [{}]: {} events, {} requests in {:.3}s host time -> {}",
            design.name(),
            report.events,
            report.requests.len(),
            dt,
            fmt_rate(report.events as f64 / dt)
        );
        sim_stats.push(Json::from_pairs(vec![
            ("design", design.name().into()),
            ("events", report.events.into()),
            ("requests", report.requests.len().into()),
            ("host_secs", dt.into()),
            ("events_per_sec", (report.events as f64 / dt).into()),
        ]));
    }

    // JSON parse of a config-sized document.
    let doc = SystemConfig::workload_experiment(6, 4, 32).to_json().pretty();
    b.bench("json: parse system config", || {
        black_box(Json::parse(&doc).unwrap());
    });

    // Gamma sampling (workload generation inner loop).
    b.bench("rng: gamma sample (cv=4)", {
        let mut rng = computron::util::rng::Rng::seeded(3);
        move || {
            black_box(rng.gamma(0.0625, 16.0));
        }
    });

    common::save_bench_json(
        "perf_hotpath",
        Json::from_pairs(vec![
            ("experiment", "perf_hotpath".into()),
            ("fast", fast.into()),
            ("micro", b.to_json()),
            ("sim", Json::Arr(sim_stats)),
        ]),
    );
    println!("\nsummaries recorded; see EXPERIMENTS.md §Perf for targets");
}
