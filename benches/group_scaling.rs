//! Group scaling — the multi-group cluster layer's headline experiment
//! (DESIGN.md §8): replicate a skewed heterogeneous catalog across
//! G ∈ {1, 2, 4} model-parallel groups and sweep the router registry
//! under overload.
//!
//! Workloads: `zipf` (long-tail popularity) and `flash-crowd` (sudden
//! hotspot) at an offered load far above even the 4-group capacity, with
//! a uniform 1 s SLO and the `shed` admission controller — so served
//! goodput tracks cluster *capacity*, the quantity placement/replication
//! exists to scale.
//!
//! Oracles asserted on every cell:
//!
//! - engine invariants: no dependency violations, no OOM, swaps drained,
//!   completions + drops cover every arrival;
//! - per-group swap-bytes accounting: each group's per-GPU H2D traffic
//!   decomposes exactly into (its own completed swap-ins) × (that
//!   model's per-worker shard bytes), and `GroupStats::swap_bytes` sums
//!   the same records;
//! - scaling: for each scenario there is at least one router whose
//!   aggregate goodput strictly increases 1 → 2 → 4 groups.
//!
//! ```bash
//! cargo bench --bench group_scaling              # full sweep
//! cargo bench --bench group_scaling -- --fast    # CI smoke subset
//! ```

#[path = "common.rs"]
mod common;

use computron::config::{
    ModelCatalog, ModelDeployment, PlacementSpec, RouterKind, SchedulerKind, SystemConfig,
};
use computron::coordinator::router;
use computron::metrics::{group_cells, load_imbalance};
use computron::model::shard_grid;
use computron::sim::{Driver, SimCluster, SimReport};
use computron::util::bench::{section, table};
use computron::util::json::Json;
use computron::workload::scenarios::{self, ScenarioParams, WorkloadGen};

const SEED: u64 = 0x6A0C_5CA1;

/// Skewed hetero catalog: hot small models, cold large tail (4:3:2:1
/// shares), uniform 1 s SLO.
fn fleet() -> ModelCatalog {
    ModelCatalog::new(vec![
        ModelDeployment::new("opt-1.3b").with_slo(1.0).with_rate_share(4.0),
        ModelDeployment::new("opt-1.3b").with_slo(1.0).with_rate_share(3.0),
        ModelDeployment::new("opt-2.7b").with_slo(1.0).with_rate_share(2.0),
        ModelDeployment::new("opt-6.7b").with_slo(1.0).with_rate_share(1.0),
    ])
}

fn cluster_cfg(g: usize, router: RouterKind) -> SystemConfig {
    let mut cfg = SystemConfig::hetero_experiment(fleet(), 2, 8);
    cfg.engine.scheduler = SchedulerKind::Shed;
    cfg.placement = Some(PlacementSpec::replicated(g, cfg.parallel, 4, router));
    cfg
}

struct Cell {
    goodput: f64,
    attained: usize,
    drops: usize,
    requests: usize,
    imbalance: f64,
}

fn run_cell(
    scenario: &str,
    rate_scale: f64,
    g: usize,
    router: RouterKind,
    duration: f64,
) -> Cell {
    let cfg = cluster_cfg(g, router);
    let params = ScenarioParams {
        num_models: 4,
        duration,
        seed: SEED,
        rate_scale,
        rate_shares: cfg.models.rate_shares(),
        ..ScenarioParams::default()
    };
    let gen = scenarios::by_name(scenario, &params).expect("scenario resolves");
    let arrivals = gen.generate();
    let total_arrivals = arrivals.len();
    let start = gen.measure_start();
    let mut sys = SimCluster::new(cfg, Driver::Open(arrivals)).expect("config valid");
    sys.preload_warm();
    let report = sys.run();
    oracle_checks(scenario, g, router, &report, total_arrivals);
    let attained = report
        .requests
        .iter()
        .filter(|r| r.arrival >= start && r.attained())
        .count();
    let cells = group_cells(&report, start, duration);
    Cell {
        goodput: attained as f64 / duration,
        attained,
        drops: report.drops.iter().filter(|d| d.arrival >= start).count(),
        requests: report.requests.iter().filter(|r| r.arrival >= start).count(),
        imbalance: load_imbalance(&cells),
    }
}

fn oracle_checks(
    scenario: &str,
    g: usize,
    router: RouterKind,
    report: &SimReport,
    total_arrivals: usize,
) {
    let tag = format!("{scenario}/G={g}/{}", router.name());
    assert_eq!(report.violations, 0, "{tag}: load-dependency violations");
    assert_eq!(report.oom_events, 0, "{tag}: OOM events");
    assert_eq!(report.groups.len(), g, "{tag}: group count");
    assert_eq!(
        report.requests.len() + report.drops.len(),
        total_arrivals,
        "{tag}: completions + drops must cover every arrival"
    );
    let s = report.swap_stats;
    assert_eq!(s.loads_started, s.loads_completed + s.loads_cancelled, "{tag}: loads drained");
    assert_eq!(s.offloads_started, s.offloads_completed, "{tag}: offloads drained");

    // Per-group swap-bytes accounting (async design: every load moves the
    // full shard). For each group: its per-GPU H2D counters must equal
    // the sum over its completed swap-ins of that model's per-worker
    // shard bytes, and GroupStats::swap_bytes must sum the same records'
    // max-shard bytes.
    let specs: Vec<_> = fleet()
        .specs()
        .expect("catalog resolves")
        .into_iter()
        .map(|spec| shard_grid(&spec, 2, 2).expect("grid divides"))
        .collect();
    for gs in &report.groups {
        let world = gs.h2d_bytes.len();
        assert_eq!(world, 4, "{tag}: tp2 x pp2 workers per group");
        let mut expect_h2d = vec![0u64; world];
        let mut expect_bytes = 0u64;
        for sw in report.swaps.iter().filter(|sw| sw.group == gs.group && !sw.cancelled) {
            let grid = &specs[sw.load_model];
            let mut max_shard = 0usize;
            for pp_rank in 0..2 {
                for tp_rank in 0..2 {
                    let b = grid[pp_rank][tp_rank].bytes();
                    expect_h2d[pp_rank * 2 + tp_rank] += b as u64;
                    max_shard = max_shard.max(b);
                }
            }
            expect_bytes += max_shard as u64;
            assert_eq!(sw.bytes, max_shard, "{tag}: swap record carries foreign bytes");
        }
        assert_eq!(gs.h2d_bytes, expect_h2d, "{tag}: group {} H2D decomposition", gs.group);
        assert_eq!(gs.swap_bytes, expect_bytes, "{tag}: group {} swap_bytes", gs.group);
        assert_eq!(
            gs.swaps,
            report.swaps.iter().filter(|sw| sw.group == gs.group && !sw.cancelled).count(),
            "{tag}: group swap count"
        );
    }
}

fn main() {
    let fast = common::fast_mode();
    let duration = if fast { 6.0 } else { 20.0 };
    // (scenario, rate_scale): offered load far above 4-group capacity so
    // goodput is capacity-bound at every G.
    let scenarios_swept: &[(&str, f64)] =
        if fast { &[("zipf", 60.0)] } else { &[("zipf", 60.0), ("flash-crowd", 32.0)] };
    let group_counts = [1usize, 2, 4];

    section(&format!(
        "Group scaling: skewed hetero catalog x {} scenarios x {} routers, G in {group_counts:?}, {duration} s cells",
        scenarios_swept.len(),
        router::names().len()
    ));

    let mut rows: Vec<Vec<String>> = Vec::new();
    let mut cells_json: Vec<Json> = Vec::new();
    let mut all_monotone = Vec::new();
    for &(scenario, rate_scale) in scenarios_swept {
        let mut monotone_routers: Vec<&str> = Vec::new();
        for &kind in router::KINDS.iter() {
            let mut goodputs = Vec::new();
            for &g in &group_counts {
                let cell = run_cell(scenario, rate_scale, g, kind, duration);
                rows.push(vec![
                    scenario.to_string(),
                    kind.name().to_string(),
                    g.to_string(),
                    format!("{:.1}", cell.goodput),
                    cell.attained.to_string(),
                    cell.requests.to_string(),
                    cell.drops.to_string(),
                    format!("{:.2}", cell.imbalance),
                ]);
                cells_json.push(Json::from_pairs(vec![
                    ("scenario", scenario.into()),
                    ("router", kind.name().into()),
                    ("groups", g.into()),
                    ("goodput", cell.goodput.into()),
                    ("attained", cell.attained.into()),
                    ("requests", cell.requests.into()),
                    ("drops", cell.drops.into()),
                    ("imbalance", cell.imbalance.into()),
                ]));
                goodputs.push(cell.goodput);
            }
            if goodputs.windows(2).all(|w| w[1] > w[0]) {
                monotone_routers.push(kind.name());
            }
        }
        assert!(
            !monotone_routers.is_empty(),
            "{scenario}: no router shows strictly increasing goodput across {group_counts:?}"
        );
        println!(
            "{scenario}: goodput strictly increases 1->2->4 under {:?}",
            monotone_routers
        );
        all_monotone.push((scenario.to_string(), monotone_routers.join(",")));
    }

    table(
        &["scenario", "router", "groups", "goodput (req/s)", "attained", "served", "drops", "imbalance"],
        &rows,
    );
    println!(
        "\noracles held on every cell: engine invariants, arrival accounting, and \
         per-group swap-bytes decomposition"
    );
    // Sanity anchor outside any run: replication multiplies raw GPU count.
    assert_eq!(cluster_cfg(4, RouterKind::RoundRobin).resolved_placement().world(), 16);

    let payload = Json::from_pairs(vec![
        ("experiment", "group_scaling".into()),
        ("duration", duration.into()),
        ("fast", fast.into()),
        (
            "monotone",
            Json::Arr(
                all_monotone
                    .iter()
                    .map(|(s, r)| {
                        Json::from_pairs(vec![
                            ("scenario", s.as_str().into()),
                            ("routers", r.as_str().into()),
                        ])
                    })
                    .collect(),
            ),
        ),
        ("cells", Json::Arr(cells_json)),
    ]);
    common::save_report("group_scaling", payload.clone());
    common::save_bench_json("group_scaling", payload);
}
