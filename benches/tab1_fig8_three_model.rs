//! Tab 1 + Fig 8 — simulated workloads: 3 OPT-13B models, at most 2 in
//! GPU memory, max batch 8, TP=2 PP=2 (§5.2).
//!
//! Grid: skew ∈ {(1,1,1), (10,1,1), (10,10,1)} × CV ∈ {0.25, 1, 4} over a
//! 30 s Gamma arrival process. Prints the average-latency table (Tab 1)
//! and writes the latency CDFs (Fig 8) to reports/.
//!
//! Expected shape (paper): latency *decreases* as CV rises (bursts hit
//! the same resident model repeatedly, so fewer swaps per request); skew
//! has only a marginal effect (Computron tolerates imbalanced rates).

#[path = "common.rs"]
mod common;

use computron::metrics::{latency_table, WorkloadCell};
use computron::util::bench::{section, table};
use computron::util::json::Json;
use computron::workload::gamma::paper;

fn main() {
    section("Tab 1 / Fig 8: 3 models, cap 2, max batch 8, TP=2 PP=2, 30 s Gamma workloads");
    let mut cells: Vec<WorkloadCell> = Vec::new();
    for rates in paper::SKEWS_3 {
        for cv in paper::CVS {
            let cell = common::run_workload_cell(3, 2, 8, &rates, cv, 0xF168);
            println!(
                "  skew={} cv={:<4} -> mean {:.3}s p99 {:.3}s over {} requests ({} swaps)",
                cell.skew_label, cv, cell.mean_latency, cell.summary.p99, cell.requests, cell.swaps
            );
            cells.push(cell);
        }
    }

    println!();
    let (headers, rows) = latency_table(&cells, &paper::CVS);
    table(&headers, &rows);

    // Shape assertions (paper's observations on Tab 1).
    for rates in paper::SKEWS_3 {
        let label = paper::skew_label(&rates);
        let get = |cv: f64| {
            cells
                .iter()
                .find(|c| c.skew_label == label && (c.cv - cv).abs() < 1e-9)
                .unwrap()
                .mean_latency
        };
        assert!(
            get(4.0) < get(0.25),
            "{label}: latency must decrease from CV=0.25 ({}) to CV=4 ({})",
            get(0.25),
            get(4.0)
        );
    }
    // Skew tolerance: within each CV column, max/min mean latency stays
    // within a modest factor (paper: "little impact").
    for cv in paper::CVS {
        let col: Vec<f64> = cells
            .iter()
            .filter(|c| (c.cv - cv).abs() < 1e-9)
            .map(|c| c.mean_latency)
            .collect();
        let (lo, hi) = (col.iter().cloned().fold(f64::MAX, f64::min), col.iter().cloned().fold(0.0, f64::max));
        assert!(hi / lo < 3.0, "cv={cv}: skew impact should be modest ({lo}..{hi})");
    }
    println!("shape checks passed: burstier -> faster; skew tolerated");

    // Chunked-pipeline oracle on the Fig 8 workload: rerun the
    // heaviest-swapping cell (uniform skew, CV=0.25 — the most regular
    // stream, so the most cold hits) with the layer-granular chunked
    // pipeline. Same arrivals, same bytes moved; cold-start overlap must
    // lower the mean latency and collapse time-to-first-chunk.
    section("Fig 8 cold-start oracle: async vs chunked-pipelined, skew (1,1,1), CV = 0.25");
    let rates = paper::SKEWS_3[0];
    // The async side of this cell is exactly the grid's first entry
    // (same skew, CV, and seed) — reuse it instead of re-simulating.
    let async_cell = cells[0].clone();
    assert!((async_cell.cv - 0.25).abs() < 1e-9 && async_cell.skew_label == paper::skew_label(&rates));
    let chunked_cell = common::run_workload_cell_with(3, 2, 8, &rates, 0.25, 0xF168, |mut c| {
        c.engine.load_design = computron::config::LoadDesign::ChunkedPipelined;
        c
    });
    table(
        &["design", "mean (s)", "p99 (s)", "swaps", "ttfc (s)", "overlap"],
        &[
            vec![
                "async (monolithic)".into(),
                common::fmt_s(async_cell.mean_latency),
                common::fmt_s(async_cell.summary.p99),
                async_cell.swaps.to_string(),
                common::fmt_s(async_cell.mean_ttfc),
                format!("{:.0}%", 100.0 * async_cell.mean_overlap),
            ],
            vec![
                "chunked-pipelined".into(),
                common::fmt_s(chunked_cell.mean_latency),
                common::fmt_s(chunked_cell.summary.p99),
                chunked_cell.swaps.to_string(),
                common::fmt_s(chunked_cell.mean_ttfc),
                format!("{:.0}%", 100.0 * chunked_cell.mean_overlap),
            ],
        ],
    );
    assert!(
        chunked_cell.mean_latency < async_cell.mean_latency,
        "chunked mean {} must beat async {} on the fig8 workload",
        chunked_cell.mean_latency,
        async_cell.mean_latency
    );
    assert!(
        chunked_cell.mean_ttfc < async_cell.mean_ttfc,
        "time-to-first-chunk must collapse: {} vs {}",
        chunked_cell.mean_ttfc,
        async_cell.mean_ttfc
    );
    println!("cold-start oracle passed: chunked pipeline reduces fig8 mean latency");

    let payload = Json::from_pairs(vec![
        ("experiment", "tab1_fig8".into()),
        ("cells", Json::Arr(cells.iter().map(WorkloadCell::to_json).collect())),
        ("chunked_oracle", Json::from_pairs(vec![
            ("async", async_cell.to_json()),
            ("chunked", chunked_cell.to_json()),
        ])),
    ]);
    common::save_report("tab1_fig8_three_model", payload.clone());
    common::save_bench_json("tab1_fig8_three_model", payload);
}
