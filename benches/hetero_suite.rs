//! Hetero suite — heterogeneous-fleet serving over the ModelCatalog API.
//!
//! Sweeps two fleets against the workload-scenario registry under both
//! the async and chunked load designs:
//!
//! - **small-skew**: four models close in size (1.3B/1.3B/2.7B/6.7B),
//!   mildly skewed rate shares — the regime where multiplexing is cheap;
//! - **large-skew**: the shipped `configs/hetero_4model.json` preset
//!   (1.3B/1.3B/6.7B/13B, 4:3:2:1 shares, skewed SLOs) — small hot
//!   models multiplexed against a big cold tail.
//!
//! Per-cell invariant oracles (the acceptance criteria for the catalog
//! redesign):
//!
//! - engine invariants: no dependency violations, no OOM, swaps drained,
//!   every arrival completes (or is shed by an SLO-aware scheduler);
//! - per-model accounting: every `SwapRecord` carries its own model's
//!   shard bytes;
//! - size ordering: mean swap-in time (time-to-first-chunk) is
//!   non-decreasing in shard bytes across the fleet, and the smallest
//!   model swaps STRICTLY faster than the largest in the same run.
//!
//! ```bash
//! cargo bench --bench hetero_suite              # full sweep
//! cargo bench --bench hetero_suite -- --fast    # CI smoke subset
//! ```

#[path = "common.rs"]
mod common;

use computron::config::{LoadDesign, ModelCatalog, ModelDeployment, SystemConfig};
use computron::metrics::WorkloadCell;
use computron::model::{catalog, max_shard_bytes};
use computron::sim::{SimReport, SimSystem};
use computron::util::bench::{section, table};
use computron::util::json::Json;

const SEED: u64 = 0x4E7E_805;

fn small_skew_fleet() -> SystemConfig {
    let models = ModelCatalog::new(vec![
        ModelDeployment::new("opt-1.3b").with_rate_share(2.0),
        ModelDeployment::new("opt-1.3b").with_rate_share(2.0),
        ModelDeployment::new("opt-2.7b"),
        ModelDeployment::new("opt-6.7b"),
    ]);
    SystemConfig::hetero_experiment(models, 2, 8)
}

fn large_skew_fleet() -> SystemConfig {
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("configs")
        .join("hetero_4model.json");
    let mut cfg = SystemConfig::from_file(&path).expect("shipped hetero preset loads");
    // The suite sweeps scenario x design itself; neutralize the preset's
    // own picks so cells stay comparable across fleets.
    cfg.scenario = None;
    cfg.engine.load_design = LoadDesign::AsyncPipelined;
    cfg.engine.scheduler = computron::config::SchedulerKind::Fcfs;
    cfg
}

struct Cell {
    scenario: String,
    cell: WorkloadCell,
    /// Per-model (shard bytes, completed swap-ins, mean ttfc, mean latency).
    per_model: Vec<(usize, usize, f64, f64)>,
}

fn run_cell(
    fleet: &str,
    base: &SystemConfig,
    scenario: &str,
    design: LoadDesign,
    duration: f64,
) -> Cell {
    let mut cfg = base.clone();
    cfg.scenario = Some(scenario.to_string());
    cfg.engine.load_design = design;
    let shards: Vec<usize> = cfg.shard_bytes_per_model().expect("catalog resolves");
    let n = cfg.num_models();
    let sheds = cfg.engine.scheduler == computron::config::SchedulerKind::Shed;
    let (sys, measure_start) =
        SimSystem::from_scenario(cfg, duration, SEED).expect("scenario resolves");
    let report = sys.run();
    oracle_checks(fleet, scenario, design, &report, &shards, sheds);

    let per_model: Vec<(usize, usize, f64, f64)> = (0..n)
        .map(|m| {
            let ttfcs: Vec<f64> = report
                .swaps
                .iter()
                .filter(|s| s.load_model == m && !s.cancelled)
                .map(|s| s.time_to_first_chunk)
                .collect();
            let lats: Vec<f64> = report
                .requests
                .iter()
                .filter(|r| r.model == m && r.arrival >= measure_start)
                .map(|r| r.latency())
                .collect();
            let mean = |v: &[f64]| {
                if v.is_empty() { 0.0 } else { v.iter().sum::<f64>() / v.len() as f64 }
            };
            (shards[m], ttfcs.len(), mean(&ttfcs), mean(&lats))
        })
        .collect();

    Cell {
        scenario: scenario.to_string(),
        cell: WorkloadCell::from_report(scenario, -1.0, &report, measure_start, duration),
        per_model,
    }
}

fn oracle_checks(
    fleet: &str,
    scenario: &str,
    design: LoadDesign,
    report: &SimReport,
    shards: &[usize],
    sheds: bool,
) {
    let tag = format!("{fleet}/{scenario}/{}", design.name());
    assert_eq!(report.violations, 0, "{tag}: load-dependency violations");
    assert_eq!(report.oom_events, 0, "{tag}: OOM events");
    let s = report.swap_stats;
    assert_eq!(
        s.loads_started,
        s.loads_completed + s.loads_cancelled,
        "{tag}: loads did not drain"
    );
    assert_eq!(s.offloads_started, s.offloads_completed, "{tag}: offloads did not drain");
    if !sheds {
        assert!(report.drops.is_empty(), "{tag}: only shed may drop");
    }
    // Per-model accounting: every swap record reports its own model's
    // shard bytes.
    for sw in &report.swaps {
        assert_eq!(
            sw.bytes, shards[sw.load_model],
            "{tag}: swap of model {} carries foreign bytes",
            sw.load_model
        );
    }
    // Size ordering: mean swap-in time is non-decreasing in shard bytes,
    // strictly increasing from the smallest to the largest model (when
    // both actually swapped in this run).
    let mean_ttfc = |m: usize| {
        let v: Vec<f64> = report
            .swaps
            .iter()
            .filter(|sw| sw.load_model == m && !sw.cancelled)
            .map(|sw| sw.time_to_first_chunk)
            .collect();
        if v.is_empty() { None } else { Some(v.iter().sum::<f64>() / v.len() as f64) }
    };
    let mut sized: Vec<(usize, usize)> =
        shards.iter().copied().enumerate().map(|(m, b)| (b, m)).collect();
    sized.sort_unstable();
    let smallest = sized[0];
    let largest = sized[sized.len() - 1];
    if smallest.0 < largest.0 {
        if let (Some(lo), Some(hi)) = (mean_ttfc(smallest.1), mean_ttfc(largest.1)) {
            assert!(
                lo < hi,
                "{tag}: smallest model's swap-in ({lo:.3}s) must beat largest ({hi:.3}s)"
            );
        }
    }
}

fn main() {
    let fast = common::fast_mode();
    let duration = if fast { 8.0 } else { 20.0 };
    let scenarios: &[&str] =
        if fast { &["zipf"] } else { &["uniform", "zipf", "bursty", "flash-crowd"] };
    let designs = [LoadDesign::AsyncPipelined, LoadDesign::ChunkedPipelined];
    let fleets = [("small-skew", small_skew_fleet()), ("large-skew", large_skew_fleet())];

    section(&format!(
        "Hetero suite: 2 fleets x {} scenarios x {} designs, cap 2, TP=2 PP=2, {duration} s per cell",
        scenarios.len(),
        designs.len()
    ));
    for (name, cfg) in &fleets {
        let archs: Vec<&str> = cfg.models.iter().map(|d| d.model.as_str()).collect();
        println!("  fleet {name:<11} -> {archs:?} shares {:?}", cfg.models.rate_shares());
    }

    let mut rows: Vec<Vec<String>> = Vec::new();
    let mut cells_json: Vec<Json> = Vec::new();
    for (fleet, base) in &fleets {
        for &scenario in scenarios {
            for &design in &designs {
                let c = run_cell(fleet, base, scenario, design, duration);
                for (m, &(bytes, swaps, ttfc, lat)) in c.per_model.iter().enumerate() {
                    rows.push(vec![
                        fleet.to_string(),
                        c.scenario.clone(),
                        design.name().to_string(),
                        format!("{m}:{}", base.models[m].model),
                        format!("{:.2}", bytes as f64 / 1e9),
                        swaps.to_string(),
                        common::fmt_s(ttfc),
                        common::fmt_s(lat),
                    ]);
                }
                let mut j = c.cell.to_json();
                j.set("fleet", (*fleet).into());
                j.set("design", design.name().into());
                j.set(
                    "per_model",
                    Json::Arr(
                        c.per_model
                            .iter()
                            .enumerate()
                            .map(|(m, &(bytes, swaps, ttfc, lat))| {
                                Json::from_pairs(vec![
                                    ("model", base.models[m].model.as_str().into()),
                                    ("shard_bytes", bytes.into()),
                                    ("swaps", swaps.into()),
                                    ("mean_ttfc", ttfc.into()),
                                    ("mean_latency", lat.into()),
                                ])
                            })
                            .collect(),
                    ),
                );
                cells_json.push(j);
            }
        }
    }

    table(
        &[
            "fleet",
            "scenario",
            "design",
            "model",
            "shard (GB)",
            "swap-ins",
            "mean ttfc (s)",
            "mean lat (s)",
        ],
        &rows,
    );
    println!(
        "\noracles held on every cell: engine invariants, per-model swap bytes, and \
         small-before-large swap-in ordering"
    );
    // Sanity anchor for the size ordering outside any one run: shard
    // bytes themselves are strictly ordered across distinct architectures.
    let a = max_shard_bytes(&catalog::by_name("opt-1.3b").unwrap(), 2, 2).unwrap();
    let b = max_shard_bytes(&catalog::by_name("opt-13b").unwrap(), 2, 2).unwrap();
    assert!(a < b);

    let payload = Json::from_pairs(vec![
        ("experiment", "hetero_suite".into()),
        ("duration", duration.into()),
        ("fast", fast.into()),
        ("cells", Json::Arr(cells_json)),
    ]);
    common::save_report("hetero_suite", payload.clone());
    common::save_bench_json("hetero_suite", payload);
}
