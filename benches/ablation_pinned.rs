//! Pinned-memory ablation (§3.2): keeping offloaded parameters pinned in
//! CPU memory vs pageable buffers that pay a host staging copy on every
//! CUDA transfer.
//!
//! Expected: the pageable variant adds bytes/12 GB·s⁻¹ per transfer in
//! series, inflating every swap; pinning removes it — the design choice
//! the paper calls out explicitly.

#[path = "common.rs"]
mod common;

use computron::baselines;
use computron::util::bench::{section, table};
use computron::util::json::Json;

fn main() {
    let fast = common::fast_mode();
    section("Ablation: pinned vs pageable host memory, TP=2 PP=2 worst-case swaps");
    let pinned = common::swap_point(2, 2, |c| c);
    let pageable = common::swap_point(2, 2, baselines::unpinned);

    let rows = vec![
        vec!["pinned (Computron)".to_string(), common::fmt_s(pinned.mean_swap), common::fmt_s(pinned.mean_e2e)],
        vec!["pageable".to_string(), common::fmt_s(pageable.mean_swap), common::fmt_s(pageable.mean_e2e)],
        vec![
            "overhead".to_string(),
            format!("{:.2}x", pageable.mean_swap / pinned.mean_swap),
            format!("{:.2}x", pageable.mean_e2e / pinned.mean_e2e),
        ],
    ];
    table(&["variant", "mean swap (s)", "mean e2e (s)"], &rows);

    assert!(pageable.mean_swap > pinned.mean_swap * 1.5, "staging copy must be costly");
    println!("shape checks passed: pinning removes the staging copy");

    let payload = Json::from_pairs(vec![
        ("experiment", "ablation_pinned".into()),
        ("fast", fast.into()),
        ("pinned_mean_swap", pinned.mean_swap.into()),
        ("pageable_mean_swap", pageable.mean_swap.into()),
    ]);
    common::save_report("ablation_pinned", payload.clone());
    common::save_bench_json("ablation_pinned", payload);
}
