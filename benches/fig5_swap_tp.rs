//! Fig 5 — swapping latency with changing TP scale (§5.1).
//!
//! Left panel: mean swap time vs TP ∈ {1, 2, 4} (PP = 1) against the
//! ideal 24 GB / (n · 32 GB/s) target. Right panel: swap vs execution
//! proportions of end-to-end latency.
//!
//! Expected shape (paper): swap time decreases with TP but sublinearly —
//! each TP shard still carries all 644 tensor messages, so the α term is
//! constant; TP=1 sits noticeably above the 0.75 s lower bound; swapping
//! dominates e2e latency everywhere, but its share shrinks as TP grows.
//!
//! The chunked column (this repo's layer-granular swap pipeline,
//! DESIGN.md §6) moves the same bytes — mean swap time is unchanged —
//! but hides transfer behind compute: time-to-first-chunk collapses and
//! cold-start end-to-end latency drops at every TP degree.

#[path = "common.rs"]
mod common;

use computron::config::LoadDesign;
use computron::util::bench::{section, table};
use computron::util::json::Json;

fn main() {
    section("Fig 5: swapping latency vs TP (PP = 1), OPT-13B worst case");
    let points: Vec<_> = [1usize, 2, 4]
        .iter()
        .map(|&tp| common::swap_point(tp, 1, |c| c))
        .collect();
    let chunked: Vec<_> = [1usize, 2, 4]
        .iter()
        .map(|&tp| {
            common::swap_point(tp, 1, |mut c| {
                c.engine.load_design = LoadDesign::ChunkedPipelined;
                c
            })
        })
        .collect();

    let rows: Vec<Vec<String>> = points
        .iter()
        .zip(&chunked)
        .map(|(p, c)| {
            vec![
                format!("TP={}", p.tp),
                common::fmt_s(p.mean_swap),
                common::fmt_s(p.ideal),
                format!("{:.2}x", p.mean_swap / p.ideal),
                common::fmt_s(p.mean_e2e),
                format!("{:.0}%", 100.0 * p.mean_swap / p.mean_e2e),
                common::fmt_s(c.mean_e2e),
                common::fmt_s(c.mean_ttfc),
                format!("{:.0}%", 100.0 * c.mean_overlap),
            ]
        })
        .collect();
    table(
        &[
            "config",
            "swap (s)",
            "ideal (s)",
            "vs ideal",
            "e2e (s)",
            "swap share",
            "chunked e2e (s)",
            "chunked ttfc (s)",
            "overlap",
        ],
        &rows,
    );

    // Shape assertions from the paper.
    assert!(points[1].mean_swap < points[0].mean_swap, "TP=2 beats TP=1");
    assert!(points[2].mean_swap < points[1].mean_swap, "TP=4 beats TP=2");
    assert!(
        points[2].mean_swap > points[0].mean_swap / 4.0,
        "scaling is sublinear (α term persists)"
    );
    assert!(points[0].mean_swap > 0.75, "TP=1 sits above the bandwidth lower bound");
    for p in &points {
        assert!(p.mean_swap / p.mean_e2e > 0.5, "swapping remains the bottleneck");
    }
    let share = |p: &computron::metrics::SwapScalingPoint| p.mean_swap / p.mean_e2e;
    assert!(share(&points[2]) < share(&points[0]), "swap share shrinks with more GPUs");

    // Chunked-pipeline oracle: cold-start latency drops at every TP
    // degree while the transfer itself (same bytes, same α term) does not
    // get cheaper — the win is overlap, not bandwidth.
    for (p, c) in points.iter().zip(&chunked) {
        assert!(
            c.mean_e2e < p.mean_e2e,
            "TP={}: chunked e2e {} must beat monolithic {}",
            p.tp,
            c.mean_e2e,
            p.mean_e2e
        );
        assert!(
            c.mean_ttfc < p.mean_ttfc * 0.6,
            "TP={}: time-to-first-chunk {} should collapse vs {}",
            p.tp,
            c.mean_ttfc,
            p.mean_ttfc
        );
        assert!(c.mean_overlap > 0.0, "TP={}: transfer must hide behind compute", p.tp);
    }
    println!(
        "shape checks passed: sublinear TP scaling, swap-dominated e2e, chunked pipeline \
         cuts cold-start latency at every TP degree"
    );

    let payload = Json::from_pairs(vec![
        ("figure", "fig5".into()),
        ("points", Json::Arr(points.iter().map(|p| p.to_json()).collect())),
        ("chunked", Json::Arr(chunked.iter().map(|p| p.to_json()).collect())),
    ]);
    common::save_report("fig5_swap_tp", payload.clone());
    common::save_bench_json("fig5_swap_tp", payload);
}
