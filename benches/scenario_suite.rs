//! Scenario suite — sweep every named workload scenario in
//! `workload::scenarios` through the full simulator and report
//! per-scenario latency and swap statistics via the `metrics` module.
//!
//! This is the catalog every future change can be tested against: one
//! run shows how a policy/design tweak behaves under uniform, skewed,
//! bursty, Zipf-tailed, on/off-modulated, diurnal, and flash-crowd
//! traffic, with the engine invariants (no dependency violations, no
//! OOM, all swaps drained, all requests completed) asserted per cell.
//!
//! ```bash
//! cargo bench --bench scenario_suite
//! ```

#[path = "common.rs"]
mod common;

use computron::config::SystemConfig;
use computron::metrics::WorkloadCell;
use computron::sim::SimSystem;
use computron::util::bench::{section, table};
use computron::util::json::Json;
use computron::workload::scenarios;

const DURATION: f64 = 30.0;
const SEED: u64 = 0x5CEA_A210;

fn run_cell(name: &str) -> (WorkloadCell, u64, u64) {
    let mut cfg = SystemConfig::workload_experiment(3, 2, 8);
    cfg.scenario = Some(name.to_string());
    let (sys, measure_start) =
        SimSystem::from_scenario(cfg, DURATION, SEED).expect("scenario resolves");
    let report = sys.run();

    // Engine-invariant oracle per cell.
    assert_eq!(report.violations, 0, "{name}: load-dependency violations");
    assert_eq!(report.oom_events, 0, "{name}: OOM events");
    assert_eq!(
        report.swap_stats.loads_started,
        report.swap_stats.loads_completed + report.swap_stats.loads_cancelled,
        "{name}: loads did not drain"
    );
    assert_eq!(
        report.swap_stats.offloads_started, report.swap_stats.offloads_completed,
        "{name}: offloads did not drain"
    );

    let events = report.events;
    let total_requests = report.requests.len() as u64;
    // -1.0 marks "CV not applicable" for non-Gamma scenarios in reports.
    let cv = scenarios::nominal_cv(name).unwrap_or(-1.0);
    (WorkloadCell::from_report(name, cv, &report, measure_start, DURATION), total_requests, events)
}

fn main() {
    section("Scenario suite: 3 models, cap 2, max batch 8, TP=2 PP=2, 30 s per scenario");

    let mut rows: Vec<Vec<String>> = Vec::new();
    let mut cells: Vec<WorkloadCell> = Vec::new();
    for &name in scenarios::names() {
        let (cell, total, events) = run_cell(name);
        assert!(cell.requests > 0, "{name}: no measured requests");
        println!(
            "  {name:<14} -> mean {:.3}s p99 {:.3}s over {} requests ({} swaps)",
            cell.mean_latency, cell.summary.p99, cell.requests, cell.swaps
        );
        rows.push(vec![
            name.to_string(),
            cell.requests.to_string(),
            common::fmt_s(cell.mean_latency),
            common::fmt_s(cell.summary.p50),
            common::fmt_s(cell.summary.p99),
            cell.swaps.to_string(),
            format!("{:.2}", cell.swaps as f64 / cell.requests as f64),
            total.to_string(),
            events.to_string(),
        ]);
        cells.push(cell);
    }

    println!();
    table(
        &[
            "scenario",
            "requests",
            "mean (s)",
            "p50 (s)",
            "p99 (s)",
            "swaps",
            "swaps/req",
            "total reqs",
            "sim events",
        ],
        &rows,
    );

    // Cross-scenario shape checks: burstiness helps (fewer swaps per
    // request than the regular uniform stream), and the Zipf tail keeps
    // hot models resident at least as well as the uniform baseline.
    let by = |n: &str| cells.iter().find(|c| c.skew_label == n).unwrap();
    let spr = |c: &WorkloadCell| c.swaps as f64 / c.requests.max(1) as f64;
    assert!(
        spr(by("bursty")) < spr(by("uniform")),
        "bursty ({}) must swap less per request than uniform ({})",
        spr(by("bursty")),
        spr(by("uniform"))
    );
    assert!(
        spr(by("zipf")) < spr(by("uniform")),
        "zipf skew concentrates hits on resident models"
    );
    println!("shape checks passed: invariants hold on every scenario; burstiness and skew reduce swap rate");

    let payload = Json::from_pairs(vec![
        ("experiment", "scenario_suite".into()),
        ("duration", DURATION.into()),
        ("cells", Json::Arr(cells.iter().map(WorkloadCell::to_json).collect())),
    ]);
    common::save_report("scenario_suite", payload.clone());
    common::save_bench_json("scenario_suite", payload);
}
