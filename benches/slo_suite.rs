//! SLO suite — sweep every scheduling discipline in
//! `coordinator::scheduler` across every named workload scenario in
//! `workload::scenarios` and report the SLO-serving metrics (deadline
//! attainment, goodput, drop rate) per cell.
//!
//! This is the evaluation grid the scheduling subsystem is judged on:
//! `fcfs` is the paper's engine (the baseline every other discipline is
//! compared against), `edf` reorders by per-model deadlines,
//! `swap-aware` amortizes swap costs over packed batches, and `shed`
//! trades tail latency for a measured drop rate. SLOs are deliberately
//! non-uniform (model 0 tight, the rest loose) so `edf` actually
//! diverges from `fcfs`. See EXPERIMENTS.md §SLO suite for how to read
//! the numbers against Tab 1 / Tab 2.
//!
//! ```bash
//! cargo bench --bench slo_suite
//! ```

#[path = "common.rs"]
mod common;

use computron::config::{SchedulerKind, SystemConfig};
use computron::coordinator::scheduler;
use computron::metrics::WorkloadCell;
use computron::sim::{SimReport, SimSystem};
use computron::util::bench::{section, table};
use computron::util::json::Json;
use computron::workload::scenarios;

const DURATION: f64 = 20.0;
const SEED: u64 = 0x510_517E;
/// Model 0 gets a tight SLO, the rest a loose one (seconds).
const TIGHT_SLO: f64 = 1.0;
const LOOSE_SLO: f64 = 3.0;

fn run_cell(scenario: &str, kind: SchedulerKind) -> (WorkloadCell, SimReport) {
    let mut cfg = SystemConfig::workload_experiment(3, 2, 8);
    cfg.scenario = Some(scenario.to_string());
    cfg.engine.scheduler = kind;
    let mut slos = vec![LOOSE_SLO; cfg.num_models()];
    slos[0] = TIGHT_SLO;
    cfg.set_slos(&slos).expect("one SLO per catalog entry");
    let (sys, measure_start) =
        SimSystem::from_scenario(cfg, DURATION, SEED).expect("scenario resolves");
    let report = sys.run();

    // Engine-invariant oracle per cell (same as scenario_suite).
    let tag = format!("{scenario}/{}", kind.name());
    assert_eq!(report.violations, 0, "{tag}: load-dependency violations");
    assert_eq!(report.oom_events, 0, "{tag}: OOM events");
    assert_eq!(
        report.swap_stats.loads_started,
        report.swap_stats.loads_completed + report.swap_stats.loads_cancelled,
        "{tag}: loads did not drain"
    );
    if kind != SchedulerKind::Shed {
        assert!(report.drops.is_empty(), "{tag}: only shed may drop requests");
    }

    let cv = scenarios::nominal_cv(scenario).unwrap_or(-1.0);
    (WorkloadCell::from_report(scenario, cv, &report, measure_start, DURATION), report)
}

fn main() {
    section(&format!(
        "SLO suite: 3 models (SLOs {TIGHT_SLO}s/{LOOSE_SLO}s/{LOOSE_SLO}s), cap 2, \
         max batch 8, TP=2 PP=2, {DURATION} s per cell"
    ));

    let mut rows: Vec<Vec<String>> = Vec::new();
    let mut cells_json: Vec<Json> = Vec::new();
    for &scenario in scenarios::names() {
        // Total arrivals are scheduler-independent (same seed, same
        // generator): completions + drops must cover them identically.
        let mut totals: Vec<usize> = Vec::new();
        for &name in scheduler::names() {
            let kind = SchedulerKind::parse(name).expect("registry name parses");
            let (cell, report) = run_cell(scenario, kind);
            totals.push(report.requests.len() + report.drops.len());
            rows.push(vec![
                scenario.to_string(),
                name.to_string(),
                cell.requests.to_string(),
                common::fmt_s(cell.mean_latency),
                common::fmt_s(cell.summary.p99),
                format!("{:.1}%", 100.0 * cell.attainment),
                format!("{:.2}", cell.goodput),
                cell.drops.to_string(),
                format!("{:.1}%", 100.0 * cell.drop_rate),
            ]);
            let mut j = cell.to_json();
            j.set("scenario", scenario.into());
            j.set("scheduler", name.into());
            cells_json.push(j);
        }
        assert!(
            totals.windows(2).all(|w| w[0] == w[1]),
            "{scenario}: completions+drops must equal total arrivals for every \
             scheduler, got {totals:?}"
        );
    }

    table(
        &[
            "scenario",
            "scheduler",
            "served",
            "mean (s)",
            "p99 (s)",
            "attainment",
            "goodput (r/s)",
            "drops",
            "drop rate",
        ],
        &rows,
    );
    println!(
        "\ninvariants held on every scenario x scheduler cell: no dependency \
         violations, no OOM, swaps drained, every arrival served or (shed only) dropped"
    );

    let payload = Json::from_pairs(vec![
        ("experiment", "slo_suite".into()),
        ("duration", DURATION.into()),
        ("tight_slo", TIGHT_SLO.into()),
        ("loose_slo", LOOSE_SLO.into()),
        ("cells", Json::Arr(cells_json)),
    ]);
    common::save_report("slo_suite", payload.clone());
    common::save_bench_json("slo_suite", payload);
}
