//! Fig 6 — swapping latency with changing PP scale (§5.1).
//!
//! Expected shape (paper): swap time decreases with PP ∈ {1, 2, 4} but
//! sublinearly — load entries pipeline through worker stages, so each
//! additional stage adds a pipe-hop delay, and load entries must wait
//! their turn in each worker's FIFO inbox.

#[path = "common.rs"]
mod common;

use computron::util::bench::{section, table};
use computron::util::json::Json;

fn main() {
    section("Fig 6: swapping latency vs PP (TP = 1), OPT-13B worst case");
    let points: Vec<_> = [1usize, 2, 4]
        .iter()
        .map(|&pp| common::swap_point(1, pp, |c| c))
        .collect();

    let rows: Vec<Vec<String>> = points
        .iter()
        .map(|p| {
            vec![
                format!("PP={}", p.pp),
                common::fmt_s(p.mean_swap),
                common::fmt_s(p.ideal),
                format!("{:.2}x", p.mean_swap / p.ideal),
                common::fmt_s(p.mean_exec),
                common::fmt_s(p.mean_e2e),
                format!("{:.0}%", 100.0 * p.mean_swap / p.mean_e2e),
            ]
        })
        .collect();
    table(
        &["config", "swap (s)", "ideal (s)", "vs ideal", "exec (s)", "e2e (s)", "swap share"],
        &rows,
    );

    assert!(points[1].mean_swap < points[0].mean_swap, "PP=2 beats PP=1");
    assert!(points[2].mean_swap < points[1].mean_swap, "PP=4 beats PP=2");
    assert!(
        points[2].mean_swap > points[0].mean_swap / 4.0,
        "scaling is sublinear (pipelined load-entry delays)"
    );
    println!("shape checks passed: sublinear PP scaling");

    common::save_report(
        "fig6_swap_pp",
        Json::from_pairs(vec![
            ("figure", "fig6".into()),
            ("points", Json::Arr(points.iter().map(|p| p.to_json()).collect())),
        ]),
    );
}
