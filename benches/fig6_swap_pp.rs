//! Fig 6 — swapping latency with changing PP scale (§5.1).
//!
//! Expected shape (paper): swap time decreases with PP ∈ {1, 2, 4} but
//! sublinearly — load entries pipeline through worker stages, so each
//! additional stage adds a pipe-hop delay, and load entries must wait
//! their turn in each worker's FIFO inbox.
//!
//! The chunked column shows the layer-granular swap pipeline
//! (DESIGN.md §6) beating the monolithic design on end-to-end cold-start
//! latency at every PP degree, with unchanged swap (transfer) time.

#[path = "common.rs"]
mod common;

use computron::config::LoadDesign;
use computron::util::bench::{section, table};
use computron::util::json::Json;

fn main() {
    section("Fig 6: swapping latency vs PP (TP = 1), OPT-13B worst case");
    let points: Vec<_> = [1usize, 2, 4]
        .iter()
        .map(|&pp| common::swap_point(1, pp, |c| c))
        .collect();
    let chunked: Vec<_> = [1usize, 2, 4]
        .iter()
        .map(|&pp| {
            common::swap_point(1, pp, |mut c| {
                c.engine.load_design = LoadDesign::ChunkedPipelined;
                c
            })
        })
        .collect();

    let rows: Vec<Vec<String>> = points
        .iter()
        .zip(&chunked)
        .map(|(p, c)| {
            vec![
                format!("PP={}", p.pp),
                common::fmt_s(p.mean_swap),
                common::fmt_s(p.ideal),
                format!("{:.2}x", p.mean_swap / p.ideal),
                common::fmt_s(p.mean_e2e),
                format!("{:.0}%", 100.0 * p.mean_swap / p.mean_e2e),
                common::fmt_s(c.mean_e2e),
                common::fmt_s(c.mean_ttfc),
                format!("{:.0}%", 100.0 * c.mean_overlap),
            ]
        })
        .collect();
    table(
        &[
            "config",
            "swap (s)",
            "ideal (s)",
            "vs ideal",
            "e2e (s)",
            "swap share",
            "chunked e2e (s)",
            "chunked ttfc (s)",
            "overlap",
        ],
        &rows,
    );

    assert!(points[1].mean_swap < points[0].mean_swap, "PP=2 beats PP=1");
    assert!(points[2].mean_swap < points[1].mean_swap, "PP=4 beats PP=2");
    assert!(
        points[2].mean_swap > points[0].mean_swap / 4.0,
        "scaling is sublinear (pipelined load-entry delays)"
    );
    for (p, c) in points.iter().zip(&chunked) {
        assert!(
            c.mean_e2e < p.mean_e2e,
            "PP={}: chunked e2e {} must beat monolithic {}",
            p.pp,
            c.mean_e2e,
            p.mean_e2e
        );
        assert!(c.mean_overlap > 0.0, "PP={}: transfer must hide behind compute", p.pp);
    }
    println!("shape checks passed: sublinear PP scaling; chunked pipeline wins at every PP");

    let payload = Json::from_pairs(vec![
        ("figure", "fig6".into()),
        ("points", Json::Arr(points.iter().map(|p| p.to_json()).collect())),
        ("chunked", Json::Arr(chunked.iter().map(|p| p.to_json()).collect())),
    ]);
    common::save_report("fig6_swap_pp", payload.clone());
    common::save_bench_json("fig6_swap_pp", payload);
}
