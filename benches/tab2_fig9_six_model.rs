//! Tab 2 + Fig 9 — simulated workloads: 6 OPT-13B models, at most 4 in
//! GPU memory, max batch 32, TP=2 PP=2 (§5.2).
//!
//! Expected shape (paper): same burstiness pattern as Tab 1; at CV=4 the
//! 6-model deployment is *at least as good as* the 3-model one (good
//! utilization under bursts), while at low CV latencies roughly double
//! (the GPUs were already saturated, so 2× work ⇒ ~2× latency).

#[path = "common.rs"]
mod common;

use computron::metrics::{latency_table, WorkloadCell};
use computron::util::bench::{section, table};
use computron::util::json::Json;
use computron::workload::gamma::paper;

fn main() {
    section("Tab 2 / Fig 9: 6 models, cap 4, max batch 32, TP=2 PP=2, 30 s Gamma workloads");
    let mut cells: Vec<WorkloadCell> = Vec::new();
    for rates in paper::SKEWS_6 {
        for cv in paper::CVS {
            let cell = common::run_workload_cell(6, 4, 32, &rates, cv, 0xF169);
            println!(
                "  skew={} cv={:<4} -> mean {:.3}s p99 {:.3}s over {} requests ({} swaps)",
                cell.skew_label, cv, cell.mean_latency, cell.summary.p99, cell.requests, cell.swaps
            );
            cells.push(cell);
        }
    }

    println!();
    let (headers, rows) = latency_table(&cells, &paper::CVS);
    table(&headers, &rows);

    // Burstiness pattern (per skew row).
    for rates in paper::SKEWS_6 {
        let label = paper::skew_label(&rates);
        let get = |cv: f64| {
            cells
                .iter()
                .find(|c| c.skew_label == label && (c.cv - cv).abs() < 1e-9)
                .unwrap()
                .mean_latency
        };
        assert!(get(4.0) < get(0.25), "{label}: bursty must beat regular");
    }

    // Cross-table comparison with the 3-model experiment (paper's Tab 1
    // vs Tab 2 observations): rerun the uniform 3-model cells here.
    let three_low = common::run_workload_cell(3, 2, 8, &[1.0, 1.0, 1.0], 0.25, 0xF168);
    let three_high = common::run_workload_cell(3, 2, 8, &[1.0, 1.0, 1.0], 4.0, 0xF168);
    let six_low = &cells[0]; // (1,1,1,1,1,1) cv=0.25
    let six_high = &cells[2]; // (1,1,1,1,1,1) cv=4
    println!(
        "3-model vs 6-model: cv=0.25 {:.3} -> {:.3} ({:.2}x); cv=4 {:.3} -> {:.3}",
        three_low.mean_latency,
        six_low.mean_latency,
        six_low.mean_latency / three_low.mean_latency,
        three_high.mean_latency,
        six_high.mean_latency,
    );
    assert!(
        six_high.mean_latency < six_low.mean_latency,
        "bursty 6-model case must beat its low-CV counterpart"
    );
    // Paper observes ~2x at its saturation point; our calibrated service
    // times sit lower relative to offered load, so the growth is smaller
    // but must still be clearly present (see EXPERIMENTS.md §Calibration).
    assert!(
        six_low.mean_latency > three_low.mean_latency * 1.15,
        "low-CV latencies must grow when doubling models: {} -> {}",
        three_low.mean_latency,
        six_low.mean_latency
    );
    println!("shape checks passed");

    common::save_report(
        "tab2_fig9_six_model",
        Json::from_pairs(vec![
            ("experiment", "tab2_fig9".into()),
            ("cells", Json::Arr(cells.iter().map(WorkloadCell::to_json).collect())),
        ]),
    );
}
