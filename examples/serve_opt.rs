//! End-to-end driver (the EXPERIMENTS.md §E2E deliverable): serve THREE real
//! opt-mini models (~25M parameters each) on the full stack — rust
//! engine/worker threads, TP=2 × PP=2 grid, PJRT execution of the
//! AOT-compiled jax+pallas stages — under a bursty multi-model workload
//! with a residency cap of two, and report latency/throughput plus swap
//! behaviour.
//!
//! ```bash
//! make artifacts
//! cargo run --release --example serve_opt -- [--requests 48] [--model opt-mini]
//! ```

use computron::config::EngineConfig;
use computron::serving::{Computron, ServeConfig};
use computron::util::args::Args;
use computron::util::rng::Rng;
use computron::util::stats::Summary;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let args = Args::new("serve_opt", "end-to-end multi-model serving driver")
        .opt("model", "manifest model name", Some("opt-mini"))
        .opt("requests", "measured requests", Some("48"))
        .opt("tp", "tensor parallel degree", Some("2"))
        .opt("pp", "pipeline parallel degree", Some("2"))
        .opt("cap", "resident model cap", Some("2"))
        .parse()?;
    let model = args.get_or("model", "opt-mini").to_string();
    let total: usize = args.get_usize("requests")?.unwrap_or(48);
    let tp = args.get_usize("tp")?.unwrap_or(2);
    let pp = args.get_usize("pp")?.unwrap_or(2);
    let cap = args.get_usize("cap")?.unwrap_or(2);

    let dir = computron::runtime::manifest::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("artifacts not found at {}; run `make artifacts`", dir.display());
        std::process::exit(1);
    }
    let manifest = computron::runtime::Manifest::load(&dir)?;
    if !manifest.supports(&model, tp) {
        eprintln!(
            "artifacts for model '{model}' tp={tp} not built; \
             run `make artifacts` (full build) or pass --model opt-test"
        );
        std::process::exit(1);
    }
    let vocab = manifest.models[&model].vocab;

    let num_models = 3;
    let mut cfg = ServeConfig::new(&dir, &model, num_models, tp, pp);
    cfg.engine = EngineConfig { resident_cap: cap, max_batch_size: 8, ..Default::default() };
    println!(
        "launching computron: model={model} instances={num_models} tp={tp} pp={pp} cap={cap}"
    );
    let t0 = Instant::now();
    let server = Computron::launch(cfg)?;
    println!("workers ready in {:.1}s (compiled stage executables)", t0.elapsed().as_secs_f64());

    // Warmup: touch every instance once (unrecorded), like §5.2.
    let mut rng = Rng::seeded(0xE2E);
    let prompt = |rng: &mut Rng| -> Vec<i32> {
        let len = 4 + rng.index(5); // 4..8 tokens
        (0..len).map(|_| rng.u64_below(vocab as u64) as i32).collect()
    };
    println!("warmup...");
    for m in 0..num_models {
        server.submit(m, prompt(&mut rng)).wait().map_err(|e| anyhow::anyhow!(e))?;
    }

    // Measured run: bursty closed-ish workload with skewed model choice —
    // model 0 is hot (~60%), models 1..2 split the rest; bursts of 1-6
    // requests go to the same model (the CV>1 regime the paper targets).
    println!("serving {total} measured requests (bursty, skewed)...");
    let run_start = Instant::now();
    let mut latencies = Vec::new();
    let mut sent = 0usize;
    while sent < total {
        let model = match rng.index(10) {
            0..=5 => 0,
            6..=7 => 1,
            _ => 2,
        };
        let burst = 1 + rng.index(6).min(total - sent);
        let futs: Vec<_> =
            (0..burst).map(|_| server.submit(model, prompt(&mut rng))).collect();
        for f in futs {
            let out = f.wait().map_err(|e| anyhow::anyhow!(e))?;
            latencies.push(out.latency);
        }
        sent += burst;
    }
    let elapsed = run_start.elapsed().as_secs_f64();

    let stats = server.stats();
    println!("\n=== end-to-end results ({model}, tp={tp} pp={pp}, cap {cap}/{num_models}) ===");
    println!("requests:    {total} in {elapsed:.2}s -> {:.2} req/s", total as f64 / elapsed);
    if let Some(s) = Summary::of(&latencies) {
        println!(
            "latency:     mean {:.3}s  p50 {:.3}s  p90 {:.3}s  p99 {:.3}s  max {:.3}s",
            s.mean, s.p50, s.p90, s.p99, s.max
        );
    }
    println!(
        "swaps:       {} loads, {} offloads (mean load-entry transfer {:.3}s)",
        stats.swap.loads_completed, stats.swap.offloads_completed, stats.mean_load_secs
    );
    if !stats.errors.is_empty() {
        println!("errors:      {:?}", stats.errors);
    }
    assert!(stats.errors.is_empty(), "serving errors occurred");
    server.shutdown();
    println!("done. Record this run in EXPERIMENTS.md §E2E.");
    Ok(())
}
