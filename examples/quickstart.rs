//! Quickstart: launch Computron on the real PJRT path, serve a few
//! requests against two co-located model instances with a residency cap
//! of one, and watch the swaps happen.
//!
//! ```bash
//! make artifacts          # once: AOT-compile the jax/pallas stages
//! cargo run --release --example quickstart
//! ```

use computron::config::EngineConfig;
use computron::serving::{Computron, ServeConfig};

fn main() -> anyhow::Result<()> {
    let dir = computron::runtime::manifest::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("artifacts not found at {}; run `make artifacts` first", dir.display());
        std::process::exit(1);
    }

    // Two opt-test instances sharing the grid; only ONE may be resident —
    // every alternation forces a model-parallel swap, exactly the paper's
    // §5.1 worst case.
    let mut cfg = ServeConfig::new(&dir, "opt-test", 2, 1, 1);
    cfg.engine = EngineConfig { resident_cap: 1, max_batch_size: 8, ..Default::default() };
    println!("launching computron: model=opt-test instances=2 tp=1 pp=1 cap=1");
    let server = Computron::launch(cfg)?;

    let prompt: Vec<i32> = vec![11, 42, 7, 100, 3, 250, 9, 1];
    for i in 0..6 {
        let model = i % 2;
        let out = server
            .submit(model, prompt.clone())
            .wait()
            .map_err(|e| anyhow::anyhow!(e))?;
        println!(
            "request {i}: model {model} -> next-token argmax {:4}  (latency {:.3}s)",
            out.argmax, out.latency
        );
    }

    let stats = server.stats();
    println!(
        "\nserved {} requests | swaps: {} loads / {} offloads | mean load {:.3}s",
        stats.completed,
        stats.swap.loads_completed,
        stats.swap.offloads_completed,
        stats.mean_load_secs
    );
    if let Some(lat) = stats.latency {
        println!("latency: mean {:.3}s p50 {:.3}s p99 {:.3}s", lat.mean, lat.p50, lat.p99);
    }
    server.shutdown();
    println!("done.");
    Ok(())
}
