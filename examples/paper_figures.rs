//! Regenerate every table and figure of the paper's evaluation (§5) in
//! one run, printing paper-style output and writing JSON series to
//! `reports/`. This is the simulation counterpart of the bench suite —
//! handy for a quick look without `cargo bench`.
//!
//! ```bash
//! cargo run --release --example paper_figures            # everything
//! cargo run --release --example paper_figures -- --only fig5
//! ```

use computron::config::SystemConfig;
use computron::metrics::{latency_table, SwapScalingPoint, WorkloadCell};
use computron::sim::{Driver, SimSystem};
use computron::util::args::Args;
use computron::util::bench::{section, table};
use computron::workload::gamma::paper;
use computron::workload::GammaWorkload;

fn swap_report(tp: usize, pp: usize) -> SwapScalingPoint {
    let cfg = SystemConfig::swap_experiment(tp, pp);
    let bw = cfg.hardware.link.bandwidth;
    let bytes = cfg.spec().unwrap().param_bytes();
    let mut sys = SimSystem::new(cfg, Driver::AlternatingBlocking {
        models: 2,
        input_len: 2,
        total: 20,
    })
    .unwrap();
    sys.preload(&[1]);
    let r = sys.run();
    SwapScalingPoint::from_records(tp, pp, &r.swaps, &r.requests, bytes, bw)
}

fn swap_rows(points: &[SwapScalingPoint]) -> Vec<Vec<String>> {
    points
        .iter()
        .map(|p| {
            vec![
                format!("TP={},PP={}", p.tp, p.pp),
                format!("{:.3}", p.mean_swap),
                format!("{:.3}", p.ideal),
                format!("{:.2}x", p.mean_swap / p.ideal),
                format!("{:.0}%", 100.0 * p.mean_swap / p.mean_e2e),
            ]
        })
        .collect()
}

fn workload_grid(num_models: usize, cap: usize, batch: usize, skews: &[Vec<f64>], seed: u64) -> Vec<WorkloadCell> {
    let mut cells = Vec::new();
    for rates in skews {
        for &cv in &paper::CVS {
            let cfg = SystemConfig::workload_experiment(num_models, cap, batch);
            let w = GammaWorkload::new(rates.clone(), cv, seed);
            let arrivals = w.generate();
            let mut sys = SimSystem::new(cfg, Driver::Open(arrivals)).unwrap();
            sys.preload(&(0..cap).collect::<Vec<_>>());
            let r = sys.run();
            cells.push(WorkloadCell::from_report(
                &paper::skew_label(rates),
                cv,
                &r,
                w.measure_start(),
                w.duration,
            ));
        }
    }
    cells
}

fn main() -> anyhow::Result<()> {
    let args = Args::new("paper_figures", "regenerate §5 tables and figures")
        .opt("only", "fig5|fig6|fig7|tab1|tab2 (default: all)", None)
        .parse()?;
    let only = args.get("only").map(str::to_string);
    let want = |k: &str| only.as_deref().map_or(true, |o| o == k);

    let headers = ["config", "swap (s)", "ideal (s)", "vs ideal", "swap share"];

    if want("fig5") {
        section("Fig 5: swap latency vs TP");
        let pts: Vec<_> = [1, 2, 4].iter().map(|&tp| swap_report(tp, 1)).collect();
        table(&headers, &swap_rows(&pts));
    }
    if want("fig6") {
        section("Fig 6: swap latency vs PP");
        let pts: Vec<_> = [1, 2, 4].iter().map(|&pp| swap_report(1, pp)).collect();
        table(&headers, &swap_rows(&pts));
    }
    if want("fig7") {
        section("Fig 7: mixed parallelism at world size 4");
        let pts: Vec<_> =
            [(4, 1), (1, 4), (2, 2)].iter().map(|&(tp, pp)| swap_report(tp, pp)).collect();
        table(&headers, &swap_rows(&pts));
    }
    if want("tab1") {
        section("Tab 1 / Fig 8: 3 models, cap 2, batch 8");
        let skews: Vec<Vec<f64>> = paper::SKEWS_3.iter().map(|s| s.to_vec()).collect();
        let cells = workload_grid(3, 2, 8, &skews, 0xF168);
        let (h, rows) = latency_table(&cells, &paper::CVS);
        table(&h, &rows);
        println!("(CDF series in reports/ after `cargo bench --bench tab1_fig8_three_model`)");
    }
    if want("tab2") {
        section("Tab 2 / Fig 9: 6 models, cap 4, batch 32");
        let skews: Vec<Vec<f64>> = paper::SKEWS_6.iter().map(|s| s.to_vec()).collect();
        let cells = workload_grid(6, 4, 32, &skews, 0xF169);
        let (h, rows) = latency_table(&cells, &paper::CVS);
        table(&h, &rows);
    }
    println!("\nall requested figures regenerated.");
    Ok(())
}
