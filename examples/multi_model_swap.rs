//! Model-parallel swapping up close: measure real load/offload entry
//! times at TP×PP ∈ {(1,1), (2,1), (1,2), (2,2)} on the PJRT path and
//! show the cross-stage loading parallelism of the async pipelined
//! design — the real-mode analogue of the paper's Fig 5–7 experiment.
//!
//! ```bash
//! make artifacts
//! cargo run --release --example multi_model_swap
//! ```

use computron::config::EngineConfig;
use computron::serving::{Computron, ServeConfig};
use computron::util::bench::table;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let dir = computron::runtime::manifest::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("artifacts not found at {}; run `make artifacts`", dir.display());
        std::process::exit(1);
    }

    let prompt: Vec<i32> = (1..9).collect();
    let mut rows = Vec::new();
    for (tp, pp) in [(1usize, 1usize), (2, 1), (1, 2), (2, 2)] {
        let mut cfg = ServeConfig::new(&dir, "opt-test", 2, tp, pp);
        cfg.engine = EngineConfig { resident_cap: 1, max_batch_size: 8, ..Default::default() };
        let server = Computron::launch(cfg)?;
        // Warmup (loads model 0).
        server.submit(0, prompt.clone()).wait().map_err(|e| anyhow::anyhow!(e))?;

        // Alternate blocking requests: every one forces offload+load.
        let n = 12;
        let t0 = Instant::now();
        for i in 0..n {
            server
                .submit((i + 1) % 2, prompt.clone())
                .wait()
                .map_err(|e| anyhow::anyhow!(e))?;
        }
        let per_req = t0.elapsed().as_secs_f64() / n as f64;
        let stats = server.stats();
        rows.push(vec![
            format!("TP={tp},PP={pp}"),
            format!("{:.1}", stats.swap.loads_completed as f64),
            format!("{:.4}", stats.mean_load_secs),
            format!("{per_req:.4}"),
        ]);
        server.shutdown();
    }

    println!("\nreal-mode model-parallel swapping (opt-test, alternating worst case):");
    table(
        &["grid", "loads", "mean load-entry (s)", "e2e per request (s)"],
        &rows,
    );
    println!(
        "\nNote: per-worker load-entry time shrinks with the grid (smaller shards\n\
         per worker) and stages transfer concurrently — the paper's model\n\
         parallel swapping effect, here on the CPU-PJRT substrate."
    );
    Ok(())
}
