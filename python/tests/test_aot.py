"""AOT pipeline tests: stage signatures, HLO lowering, manifest integrity,
and golden-vector generation."""

import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.aot import golden_vectors, lower_artifact, stage_signature, to_hlo_text, ROLES
from compile.kernels.ref import ref_opt_forward
from compile.weights import MODEL_SPECS, WEIGHT_SEED, build_weights

CFG = MODEL_SPECS["opt-test"]


@pytest.mark.parametrize("role", ROLES)
@pytest.mark.parametrize("tp", [1, 2])
def test_stage_signatures_consistent(role, tp):
    fn, args = stage_signature(role, CFG, tp, b=2, s=8)
    assert callable(fn)
    # All shapes positive, dtypes known.
    for name, dt, shape in args:
        assert dt in ("f32", "i32"), name
        assert all(d > 0 for d in shape) or shape == [], name
    # Sharded dims divide correctly.
    if role == "attn":
        q_w = dict((a[0], a[2]) for a in args)["q_w"]
        assert q_w == [CFG["hidden"] // tp, CFG["hidden"]]
    if role == "embed":
        tok = dict((a[0], a[2]) for a in args)["embed_tokens"]
        assert tok == [CFG["vocab"] // tp, CFG["hidden"]]


@pytest.mark.parametrize("role", ROLES)
def test_lowering_produces_hlo_text(role):
    text, args = lower_artifact(role, CFG, tp=1, b=1, s=8)
    assert "HloModule" in text
    assert len(text) > 200
    assert len(args) >= 4


def test_hlo_text_has_expected_parameter_count():
    text, args = lower_artifact("mlp", CFG, tp=2, b=1, s=8)
    # One HLO parameter per declared arg.
    assert text.count("parameter(") >= len(args)


def test_golden_vectors_match_reference():
    g = golden_vectors("opt-test", CFG)
    ids = np.array(g["ids"], dtype=np.int32).reshape(g["batch"], g["seq"])
    weights = {k: jnp.array(v) for k, v in build_weights(CFG, WEIGHT_SEED).items()}
    logits = np.asarray(ref_opt_forward(jnp.array(ids), weights, CFG))
    last = logits[:, -1, :].flatten()
    stored = np.array(g["last_logits"], dtype=np.float32)
    np.testing.assert_allclose(stored, last, atol=1e-5)
    assert g["argmax"] == list(np.argmax(logits[:, -1, :], axis=-1))


def test_manifest_on_disk_is_consistent():
    manifest_path = Path(__file__).resolve().parents[2] / "artifacts" / "manifest.json"
    if not manifest_path.exists():
        pytest.skip("artifacts not built")
    m = json.loads(manifest_path.read_text())
    assert m["version"] == 1
    assert m["weight_seed"] == WEIGHT_SEED
    seen = set()
    for art in m["artifacts"]:
        key = (art["model"], art["tp"], art["role"], art["batch"], art["seq"])
        assert key not in seen, f"duplicate artifact {key}"
        seen.add(key)
        f = manifest_path.parent / art["file"]
        assert f.exists(), f"missing {f}"
        assert art["model"] in m["models"]
    for name, g in m["golden"].items():
        vocab = m["models"][name]["vocab"]
        assert len(g["last_logits"]) == g["batch"] * vocab
        assert len(g["ids"]) == g["batch"] * g["seq"]


def test_roles_cover_a_full_forward():
    # Composing embed -> attn/mlp per layer -> head over the lowered
    # functions (interpret path) must equal the reference forward.
    weights = {k: jnp.array(v) for k, v in build_weights(CFG, WEIGHT_SEED).items()}
    from compile.model import forward_sharded

    rng = np.random.default_rng(7)
    ids = jnp.array(rng.integers(0, CFG["vocab"], size=(1, 8)), dtype=jnp.int32)
    ref = ref_opt_forward(ids, weights, CFG)
    out = forward_sharded(ids, weights, CFG, tp=2)
    np.testing.assert_allclose(out, ref, atol=2e-3)
