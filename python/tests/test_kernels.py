"""L1 kernel correctness: Pallas vs pure-jnp oracle, hypothesis-swept
across shapes — the core correctness signal for the compute layer."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.attention import flash_attention, vmem_footprint_bytes
from compile.kernels.linear import fused_linear, mxu_utilization
from compile.kernels.ref import ref_attention, ref_layer_norm, ref_linear

RNG = np.random.default_rng(1234)


def randn(*shape):
    return jnp.array(RNG.normal(size=shape), dtype=jnp.float32)


# ---------- attention ----------

@settings(max_examples=30, deadline=None)
@given(
    bh=st.sampled_from([1, 2, 4, 8]),
    seq=st.sampled_from([1, 2, 4, 8, 16, 32, 64]),
    d=st.sampled_from([4, 8, 16, 32, 64]),
)
def test_attention_matches_ref(bh, seq, d):
    q, k, v = randn(bh, seq, d), randn(bh, seq, d), randn(bh, seq, d)
    out = flash_attention(q, k, v)
    ref = ref_attention(q, k, v)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)


@settings(max_examples=15, deadline=None)
@given(
    block_q=st.sampled_from([2, 4, 8, 16]),
    block_k=st.sampled_from([2, 4, 8, 16, 32]),
)
def test_attention_block_size_invariance(block_q, block_k):
    """Tiling must never change the numerics."""
    q, k, v = randn(4, 16, 8), randn(4, 16, 8), randn(4, 16, 8)
    out = flash_attention(q, k, v, block_q=block_q, block_k=block_k)
    ref = ref_attention(q, k, v)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)


def test_attention_is_causal():
    """Changing future tokens must not change earlier outputs."""
    q, k, v = randn(2, 8, 16), randn(2, 8, 16), randn(2, 8, 16)
    out1 = flash_attention(q, k, v)
    k2 = k.at[:, -1, :].set(99.0)
    v2 = v.at[:, -1, :].set(-99.0)
    out2 = flash_attention(q, k2, v2)
    np.testing.assert_allclose(out1[:, :-1], out2[:, :-1], atol=1e-5)
    assert not np.allclose(out1[:, -1], out2[:, -1])


def test_attention_first_token_copies_v():
    """Position 0 attends only to itself: output = v[0]."""
    q, k, v = randn(3, 8, 8), randn(3, 8, 8), randn(3, 8, 8)
    out = flash_attention(q, k, v)
    np.testing.assert_allclose(out[:, 0, :], v[:, 0, :], atol=1e-5)


def test_attention_uniform_values():
    """If all v rows are identical, output equals that row everywhere."""
    q, k = randn(2, 16, 8), randn(2, 16, 8)
    row = RNG.normal(size=(8,)).astype(np.float32)
    v = jnp.broadcast_to(jnp.array(row), (2, 16, 8))
    out = flash_attention(q, k, v)
    np.testing.assert_allclose(out, v, atol=1e-5)


def test_attention_vmem_estimate_fits_tpu_core():
    # 16 MiB VMEM per TPU core; paper-scale OPT-13B head_dim=128.
    assert vmem_footprint_bytes(seq=2048, head_dim=128, block_q=128, block_k=128) < 16 * 2**20


# ---------- fused linear ----------

@settings(max_examples=30, deadline=None)
@given(
    m=st.sampled_from([1, 2, 8, 32, 64]),
    n=st.sampled_from([1, 4, 16, 48, 128]),
    k=st.sampled_from([1, 8, 32, 64, 128]),
    act=st.sampled_from(["none", "relu", "gelu"]),
)
def test_linear_matches_ref(m, n, k, act):
    x, w, b = randn(m, k), randn(n, k), randn(n)
    out = fused_linear(x, w, b, activation=act)
    ref = ref_linear(x, w, b, act)
    np.testing.assert_allclose(out, ref, atol=3e-5, rtol=3e-5)


@settings(max_examples=15, deadline=None)
@given(
    bm=st.sampled_from([8, 16, 64, 128]),
    bn=st.sampled_from([8, 32, 128]),
    bk=st.sampled_from([8, 16, 64, 128]),
)
def test_linear_block_size_invariance(bm, bn, bk):
    x, w, b = randn(64, 128), randn(32, 128), randn(32)
    out = fused_linear(x, w, b, activation="relu", block_m=bm, block_n=bn, block_k=bk)
    ref = ref_linear(x, w, b, "relu")
    np.testing.assert_allclose(out, ref, atol=3e-5, rtol=3e-5)


def test_linear_relu_clamps():
    x = jnp.full((4, 8), -10.0, dtype=jnp.float32)
    w = jnp.eye(8, dtype=jnp.float32)
    b = jnp.zeros((8,), dtype=jnp.float32)
    out = fused_linear(x, w, b, activation="relu")
    assert float(jnp.max(out)) == 0.0


def test_linear_bias_applied_once():
    """Grid-carried accumulation must add bias only on the last K step."""
    x = jnp.zeros((16, 256), dtype=jnp.float32)
    w = jnp.zeros((16, 256), dtype=jnp.float32)
    b = jnp.array(RNG.normal(size=(16,)), dtype=jnp.float32)
    out = fused_linear(x, w, b, block_k=64)  # 4 K-steps
    np.testing.assert_allclose(out, jnp.broadcast_to(b, (16, 16)), atol=1e-6)


def test_linear_rejects_bad_shapes():
    with pytest.raises(AssertionError):
        fused_linear(randn(4, 8), randn(4, 16), randn(4))


def test_mxu_utilization_metric():
    assert mxu_utilization(128, 128, 128) == 1.0
    assert mxu_utilization(64, 128, 128) == 0.5
    assert 0.0 < mxu_utilization(8, 8, 8) < 0.01


# ---------- layer norm oracle sanity ----------

def test_layer_norm_normalizes():
    x = randn(4, 64)
    out = ref_layer_norm(x, jnp.ones(64), jnp.zeros(64))
    np.testing.assert_allclose(np.mean(np.asarray(out), axis=-1), 0.0, atol=1e-5)
    np.testing.assert_allclose(np.std(np.asarray(out), axis=-1), 1.0, atol=1e-3)
