"""L2 model correctness: sharded stage pipeline (with emulated
all-reduces, exactly the reductions the rust runtime performs) vs the
unsharded reference forward."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import ref_opt_forward
from compile.model import attn_half, embed_stage, forward_sharded, head_stage, mlp_half
from compile.weights import (
    MODEL_SPECS,
    WEIGHT_SEED,
    build_weights,
    shard_column,
    shard_row,
)

CFG = MODEL_SPECS["opt-test"]
WEIGHTS = {k: jnp.array(v) for k, v in build_weights(CFG, WEIGHT_SEED).items()}
RNG = np.random.default_rng(99)


def ids_of(b, s):
    return jnp.array(RNG.integers(0, CFG["vocab"], size=(b, s)), dtype=jnp.int32)


@settings(max_examples=12, deadline=None)
@given(
    tp=st.sampled_from([1, 2, 4]),
    b=st.sampled_from([1, 2, 4]),
    s=st.sampled_from([2, 8, 16]),
)
def test_sharded_forward_matches_reference(tp, b, s):
    ids = ids_of(b, s)
    ref = ref_opt_forward(ids, WEIGHTS, CFG)
    out = forward_sharded(ids, WEIGHTS, CFG, tp)
    np.testing.assert_allclose(out, ref, atol=2e-3, rtol=2e-3)


def test_embed_partials_sum_to_full_embedding():
    ids = ids_of(2, 8)
    tp = 2
    partials = []
    for r in range(tp):
        shard = shard_column(WEIGHTS["decoder.embed_tokens.weight"], tp, r)
        partials.append(
            embed_stage(
                ids,
                jnp.int32(r * CFG["vocab"] // tp),
                shard,
                WEIGHTS["decoder.embed_positions.weight"],
                tp=tp,
            )
        )
    total = sum(partials)
    expected = WEIGHTS["decoder.embed_tokens.weight"][ids] + WEIGHTS[
        "decoder.embed_positions.weight"
    ][2:10][None]
    np.testing.assert_allclose(total, expected, atol=1e-5)


def test_attn_half_partials_equal_full_block():
    ids = ids_of(1, 8)
    x = WEIGHTS["decoder.embed_tokens.weight"][ids]
    p = "decoder.layers.0"
    full = attn_half(
        x,
        WEIGHTS[f"{p}.self_attn_layer_norm.weight"],
        WEIGHTS[f"{p}.self_attn_layer_norm.bias"],
        WEIGHTS[f"{p}.self_attn.q_proj.weight"],
        WEIGHTS[f"{p}.self_attn.q_proj.bias"],
        WEIGHTS[f"{p}.self_attn.k_proj.weight"],
        WEIGHTS[f"{p}.self_attn.k_proj.bias"],
        WEIGHTS[f"{p}.self_attn.v_proj.weight"],
        WEIGHTS[f"{p}.self_attn.v_proj.bias"],
        WEIGHTS[f"{p}.self_attn.out_proj.weight"],
        WEIGHTS[f"{p}.self_attn.out_proj.bias"],
        heads_local=CFG["heads"],
        tp=1,
    )
    tp = 2
    partials = [
        attn_half(
            x,
            WEIGHTS[f"{p}.self_attn_layer_norm.weight"],
            WEIGHTS[f"{p}.self_attn_layer_norm.bias"],
            shard_column(WEIGHTS[f"{p}.self_attn.q_proj.weight"], tp, r),
            shard_column(WEIGHTS[f"{p}.self_attn.q_proj.bias"], tp, r),
            shard_column(WEIGHTS[f"{p}.self_attn.k_proj.weight"], tp, r),
            shard_column(WEIGHTS[f"{p}.self_attn.k_proj.bias"], tp, r),
            shard_column(WEIGHTS[f"{p}.self_attn.v_proj.weight"], tp, r),
            shard_column(WEIGHTS[f"{p}.self_attn.v_proj.bias"], tp, r),
            shard_row(WEIGHTS[f"{p}.self_attn.out_proj.weight"], tp, r),
            WEIGHTS[f"{p}.self_attn.out_proj.bias"],
            heads_local=CFG["heads"] // tp,
            tp=tp,
        )
        for r in range(tp)
    ]
    np.testing.assert_allclose(sum(partials), full, atol=1e-4)


def test_mlp_half_partials_equal_full_block():
    ids = ids_of(1, 8)
    x = WEIGHTS["decoder.embed_tokens.weight"][ids]
    p = "decoder.layers.1"
    full = mlp_half(
        x,
        WEIGHTS[f"{p}.final_layer_norm.weight"],
        WEIGHTS[f"{p}.final_layer_norm.bias"],
        WEIGHTS[f"{p}.fc1.weight"],
        WEIGHTS[f"{p}.fc1.bias"],
        WEIGHTS[f"{p}.fc2.weight"],
        WEIGHTS[f"{p}.fc2.bias"],
        tp=1,
    )
    tp = 4
    partials = [
        mlp_half(
            x,
            WEIGHTS[f"{p}.final_layer_norm.weight"],
            WEIGHTS[f"{p}.final_layer_norm.bias"],
            shard_column(WEIGHTS[f"{p}.fc1.weight"], tp, r),
            shard_column(WEIGHTS[f"{p}.fc1.bias"], tp, r),
            shard_row(WEIGHTS[f"{p}.fc2.weight"], tp, r),
            WEIGHTS[f"{p}.fc2.bias"],
            tp=tp,
        )
        for r in range(tp)
    ]
    np.testing.assert_allclose(sum(partials), full, atol=1e-4)


def test_head_shards_concat_to_full_logits():
    ids = ids_of(1, 8)
    x = WEIGHTS["decoder.embed_tokens.weight"][ids]
    full = head_stage(
        x,
        WEIGHTS["decoder.final_layer_norm.weight"],
        WEIGHTS["decoder.final_layer_norm.bias"],
        WEIGHTS["decoder.embed_tokens.weight"],
    )
    tp = 2
    shards = [
        head_stage(
            x,
            WEIGHTS["decoder.final_layer_norm.weight"],
            WEIGHTS["decoder.final_layer_norm.bias"],
            shard_column(WEIGHTS["decoder.embed_tokens.weight"], tp, r),
        )
        for r in range(tp)
    ]
    np.testing.assert_allclose(jnp.concatenate(shards, axis=-1), full, atol=1e-4)


def test_padding_does_not_corrupt_earlier_positions():
    """Causal masking means right-padding is harmless — the property the
    rust batcher relies on when padding batches to bucket sizes."""
    ids_short = ids_of(1, 8)
    padded = jnp.concatenate([ids_short, jnp.zeros((1, 8), jnp.int32)], axis=1)
    ref_short = ref_opt_forward(ids_short, WEIGHTS, CFG)
    ref_padded = ref_opt_forward(padded, WEIGHTS, CFG)
    np.testing.assert_allclose(ref_padded[:, :8, :], ref_short, atol=1e-3)
