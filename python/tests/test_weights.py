"""Weight-generation determinism and the cross-language golden values
pinned identically in rust (`runtime::weights` unit tests)."""

import numpy as np

from compile.weights import (
    MODEL_SPECS,
    WEIGHT_SEED,
    build_weights,
    fnv1a64,
    shard_column,
    shard_row,
    tensor_values,
)


def test_fnv1a64_golden():
    # Pinned in rust runtime::weights tests — do not change.
    assert int(fnv1a64("")) == 0xCBF29CE484222325
    assert int(fnv1a64("a")) == 0xAF63DC4C8601EC8C
    assert int(fnv1a64("decoder.embed_tokens.weight")) == 0x7767B2DCFFF82D57


def test_tensor_values_golden():
    # First four values for a known tensor/seed — pinned in rust too.
    vals = tensor_values("decoder.embed_tokens.weight", 4, 0x0C0117, 0.02)
    expected = [0.005162308, 0.016930485, 0.00085321523, -0.0058384575]
    np.testing.assert_allclose(vals, expected, atol=1e-9)


def test_deterministic_and_name_sensitive():
    a = tensor_values("x.weight", 100, 1, 1.0)
    b = tensor_values("x.weight", 100, 1, 1.0)
    c = tensor_values("y.weight", 100, 1, 1.0)
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(a, c)
    d = tensor_values("x.weight", 100, 2, 1.0)
    assert not np.array_equal(a, d)


def test_values_bounded_by_scale():
    vals = tensor_values("t", 10_000, 7, 0.5)
    assert np.all(np.abs(vals) <= 0.5)
    assert np.std(vals) > 0.1  # actually spread out


def test_build_weights_shapes_match_spec():
    cfg = MODEL_SPECS["opt-test"]
    w = build_weights(cfg, WEIGHT_SEED)
    h, f = cfg["hidden"], cfg["ffn"]
    assert w["decoder.embed_tokens.weight"].shape == (cfg["vocab"], h)
    assert w["decoder.embed_positions.weight"].shape == (cfg["max_pos"] + 2, h)
    assert w["decoder.layers.0.fc1.weight"].shape == (f, h)
    assert w["decoder.layers.0.fc2.weight"].shape == (h, f)
    # 16 tensors per layer + 4.
    assert len(w) == cfg["layers"] * 16 + 4


def test_layer_norm_weights_near_one():
    cfg = MODEL_SPECS["opt-test"]
    w = build_weights(cfg, WEIGHT_SEED)
    ln = w["decoder.layers.0.self_attn_layer_norm.weight"]
    assert np.all(np.abs(ln - 1.0) < 0.05)
    lnb = w["decoder.layers.0.self_attn_layer_norm.bias"]
    assert np.all(np.abs(lnb) < 0.05)


def test_shard_helpers_partition_exactly():
    w = np.arange(24, dtype=np.float32).reshape(6, 4)
    cols = [shard_column(w, 3, r) for r in range(3)]
    np.testing.assert_array_equal(np.concatenate(cols, axis=0), w)
    rows = [shard_row(w, 2, r) for r in range(2)]
    np.testing.assert_array_equal(np.concatenate(rows, axis=1), w)
