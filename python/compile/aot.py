"""AOT compilation: lower every stage function to HLO text + manifest.

Build-time entry point (`make artifacts`). Python never runs at serving
time: this script lowers the L2 stage functions (which call the L1 Pallas
kernels) to HLO *text* — the interchange format the rust `xla` crate's
XLA 0.5.1 can parse (jax ≥ 0.5 serialized protos use 64-bit instruction
ids it rejects; the text parser reassigns ids — see /opt/xla-example).

Artifacts, per (model, tp, batch, seq) bucket:
    {model}_tp{tp}_b{B}_s{S}_{role}.hlo.txt   role ∈ embed|attn|mlp|head

plus `manifest.json` describing every artifact's argument signature, the
model configs, the weight seed, and golden test vectors (input ids +
reference last-position logits) that the rust integration tests check
against.

Usage: python -m compile.aot --out-dir ../artifacts [--models opt-test]
       [--fast]  (fast: only the buckets the tests/examples need)
"""

import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels.ref import ref_opt_forward
from .weights import MODEL_SPECS, WEIGHT_SEED, build_weights

BATCHES = [1, 4, 8]
SEQS = [8, 32]
TPS = [1, 2]
ROLES = ["embed", "attn", "mlp", "head"]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def stage_signature(role: str, cfg: dict, tp: int, b: int, s: int):
    """(function, [(arg_name, dtype, shape), ...]) for one artifact."""
    h, f, v, heads = cfg["hidden"], cfg["ffn"], cfg["vocab"], cfg["heads"]
    mp = cfg["max_pos"]
    if role == "embed":
        fn = lambda ids, start, tok, pos: M.embed_stage(ids, start, tok, pos, tp=tp)
        args = [
            ("ids", "i32", [b, s]),
            ("vocab_start", "i32", []),
            ("embed_tokens", "f32", [v // tp, h]),
            ("embed_positions", "f32", [mp + 2, h]),
        ]
    elif role == "attn":
        fn = lambda hidden, ln_w, ln_b, qw, qb, kw, kb, vw, vb, ow, ob: M.attn_half(
            hidden, ln_w, ln_b, qw, qb, kw, kb, vw, vb, ow, ob,
            heads_local=heads // tp, tp=tp,
        )
        args = [
            ("hidden", "f32", [b, s, h]),
            ("ln_w", "f32", [h]),
            ("ln_b", "f32", [h]),
            ("q_w", "f32", [h // tp, h]),
            ("q_b", "f32", [h // tp]),
            ("k_w", "f32", [h // tp, h]),
            ("k_b", "f32", [h // tp]),
            ("v_w", "f32", [h // tp, h]),
            ("v_b", "f32", [h // tp]),
            ("o_w", "f32", [h, h // tp]),
            ("o_b", "f32", [h]),
        ]
    elif role == "mlp":
        fn = lambda hidden, ln_w, ln_b, f1w, f1b, f2w, f2b: M.mlp_half(
            hidden, ln_w, ln_b, f1w, f1b, f2w, f2b, tp=tp
        )
        args = [
            ("hidden", "f32", [b, s, h]),
            ("ln_w", "f32", [h]),
            ("ln_b", "f32", [h]),
            ("fc1_w", "f32", [f // tp, h]),
            ("fc1_b", "f32", [f // tp]),
            ("fc2_w", "f32", [h, f // tp]),
            ("fc2_b", "f32", [h]),
        ]
    elif role == "head":
        fn = M.head_stage
        args = [
            ("hidden", "f32", [b, s, h]),
            ("lnf_w", "f32", [h]),
            ("lnf_b", "f32", [h]),
            ("lm_head", "f32", [v // tp, h]),
        ]
    else:
        raise ValueError(role)
    return fn, args


def lower_artifact(role, cfg, tp, b, s):
    fn, args = stage_signature(role, cfg, tp, b, s)
    specs = [i32(*shape) if dt == "i32" else f32(*shape) for (_, dt, shape) in args]
    lowered = jax.jit(fn).lower(*specs)
    return to_hlo_text(lowered), args


def golden_vectors(name: str, cfg: dict) -> dict:
    """Reference inputs/outputs for the rust integration tests: seeded ids
    and the unsharded reference forward's last-position logits."""
    weights = {k: jnp.array(v) for k, v in build_weights(cfg, WEIGHT_SEED).items()}
    rng = np.random.default_rng(0xD00D ^ len(name))
    b, s = 2, 8
    ids = rng.integers(0, cfg["vocab"], size=(b, s)).astype(np.int32)
    logits = np.asarray(ref_opt_forward(jnp.array(ids), weights, cfg))
    last = logits[:, -1, :]  # (B, V)
    return {
        "batch": b,
        "seq": s,
        "ids": ids.flatten().tolist(),
        "last_logits": [round(float(x), 6) for x in last.flatten()],
        "argmax": np.argmax(last, axis=-1).astype(int).tolist(),
        "tolerance": 2e-3,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--models", nargs="*", default=["opt-test", "opt-mini"])
    ap.add_argument(
        "--fast",
        action="store_true",
        help="only the buckets the test-suite/examples need (b in {1,8}, s=8, tp in {1,2})",
    )
    args = ap.parse_args()

    out_dir = Path(args.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)

    batches = [1, 8] if args.fast else BATCHES
    seqs = [8] if args.fast else SEQS

    manifest = {
        "version": 1,
        "weight_seed": WEIGHT_SEED,
        "models": {},
        "artifacts": [],
        "golden": {},
        "arg_convention": (
            "Each artifact computes one stage function with weights passed "
            "as runtime arguments (one executable serves every layer). "
            "Outputs are 1-tuples (return_tuple lowering). See model.py for "
            "TP partial/all-reduce semantics."
        ),
    }

    t0 = time.time()
    count = 0
    for name in args.models:
        cfg = MODEL_SPECS[name]
        manifest["models"][name] = cfg
        print(f"[aot] golden vectors for {name}...", flush=True)
        manifest["golden"][name] = golden_vectors(name, cfg)
        for tp in TPS:
            if cfg["heads"] % tp or cfg["vocab"] % tp or cfg["ffn"] % tp:
                continue
            for b in batches:
                for s in seqs:
                    for role in ROLES:
                        fname = f"{name}_tp{tp}_b{b}_s{s}_{role}.hlo.txt"
                        text, arg_spec = lower_artifact(role, cfg, tp, b, s)
                        (out_dir / fname).write_text(text)
                        manifest["artifacts"].append(
                            {
                                "file": fname,
                                "model": name,
                                "role": role,
                                "tp": tp,
                                "batch": b,
                                "seq": s,
                                "args": arg_spec,
                            }
                        )
                        count += 1
                print(
                    f"[aot] {name} tp={tp} b={b}: {count} artifacts, "
                    f"{time.time() - t0:.1f}s",
                    flush=True,
                )

    (out_dir / "manifest.json").write_text(json.dumps(manifest, indent=1))
    print(f"[aot] wrote {count} artifacts + manifest to {out_dir} in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
