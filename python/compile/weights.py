"""Deterministic, language-portable weight generation.

Real OPT checkpoints are not available offline, so model instances use
seeded random weights (DESIGN.md §1). The generator must produce
*identical* values in python (for the reference forward and golden
vectors) and in rust (for the runtime's parameter buffers), so it is a
counter-based scheme rather than a stateful RNG:

    value[i] = uniform(-scale, scale) from splitmix64(tensor_seed + (i+1)·GOLDEN)
    tensor_seed = fnv1a64(tensor_name) XOR global_seed

LayerNorm weights get +1.0 so activations stay well-scaled. The rust twin
is `runtime::weights`; `python/tests/test_weights.py` pins golden values
that the rust unit tests also pin.
"""

import numpy as np

GOLDEN = np.uint64(0x9E3779B97F4A7C15)
FNV_OFFSET = np.uint64(0xCBF29CE484222325)
FNV_PRIME = np.uint64(0x100000001B3)


def fnv1a64(name: str) -> np.uint64:
    h = FNV_OFFSET
    for byte in name.encode("utf-8"):
        h = np.uint64((int(h) ^ byte) * int(FNV_PRIME) & 0xFFFFFFFFFFFFFFFF)
    return h


def _splitmix64_finalize(z: np.ndarray) -> np.ndarray:
    """The splitmix64 output function, vectorized over uint64."""
    mask = np.uint64(0xFFFFFFFFFFFFFFFF)
    z = (z + GOLDEN) & mask
    z = ((z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)) & mask
    z = ((z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)) & mask
    return z ^ (z >> np.uint64(31))


def tensor_values(name: str, numel: int, global_seed: int, scale: float) -> np.ndarray:
    """Flat float32 values for one tensor."""
    seed = np.uint64(int(fnv1a64(name)) ^ (global_seed & 0xFFFFFFFFFFFFFFFF))
    idx = (np.arange(1, numel + 1, dtype=np.uint64) * GOLDEN + seed) & np.uint64(
        0xFFFFFFFFFFFFFFFF
    )
    bits = _splitmix64_finalize(idx)
    unit = (bits >> np.uint64(11)).astype(np.float64) * (1.0 / (1 << 53))
    vals = (unit * 2.0 - 1.0) * scale
    return vals.astype(np.float32)


def default_scale(name: str, hidden: int) -> float:
    """Init scale: 1/sqrt(hidden) for matmul weights, 0.02 for embeddings,
    biases, and layer-norm params (LN weights additionally get +1.0 in
    `build_weights`)."""
    if "embed" in name or name.endswith(".bias") or "layer_norm" in name:
        return 0.02
    return 1.0 / float(hidden) ** 0.5


def is_layer_norm_weight(name: str) -> bool:
    return ("layer_norm.weight" in name) or name.endswith("final_layer_norm.weight")


def build_weights(spec: dict, global_seed: int) -> dict:
    """Full (unsharded) weights for a model spec dict with keys
    layers/hidden/heads/ffn/vocab/max_pos. Names and shapes exactly match
    rust `ModelSpec::tensors`."""
    h = spec["hidden"]
    f = spec["ffn"]
    out = {}

    def add(name, shape):
        vals = tensor_values(name, int(np.prod(shape)), global_seed, default_scale(name, h))
        arr = vals.reshape(shape)
        if is_layer_norm_weight(name):
            arr = arr + 1.0
        out[name] = arr

    add("decoder.embed_tokens.weight", (spec["vocab"], h))
    add("decoder.embed_positions.weight", (spec["max_pos"] + 2, h))
    for l in range(spec["layers"]):
        p = f"decoder.layers.{l}"
        for proj in ["q_proj", "k_proj", "v_proj", "out_proj"]:
            add(f"{p}.self_attn.{proj}.weight", (h, h))
            add(f"{p}.self_attn.{proj}.bias", (h,))
        add(f"{p}.self_attn_layer_norm.weight", (h,))
        add(f"{p}.self_attn_layer_norm.bias", (h,))
        add(f"{p}.fc1.weight", (f, h))
        add(f"{p}.fc1.bias", (f,))
        add(f"{p}.fc2.weight", (h, f))
        add(f"{p}.fc2.bias", (h,))
        add(f"{p}.final_layer_norm.weight", (h,))
        add(f"{p}.final_layer_norm.bias", (h,))
    add("decoder.final_layer_norm.weight", (h,))
    add("decoder.final_layer_norm.bias", (h,))
    return out


# Sharding helpers (must mirror rust model::shard conventions exactly).

def shard_column(w: np.ndarray, tp: int, rank: int) -> np.ndarray:
    """Column-parallel: split output rows (q/k/v/fc1 weights and biases)."""
    n = w.shape[0]
    assert n % tp == 0
    step = n // tp
    return w[rank * step : (rank + 1) * step]


def shard_row(w: np.ndarray, tp: int, rank: int) -> np.ndarray:
    """Row-parallel: split input columns (out_proj/fc2 weights)."""
    n = w.shape[1]
    assert n % tp == 0
    step = n // tp
    return w[:, rank * step : (rank + 1) * step]


MODEL_SPECS = {
    # Mirrors rust model::catalog test configs.
    "opt-test": dict(layers=4, hidden=128, heads=4, ffn=512, vocab=512, max_pos=64),
    "opt-mini": dict(layers=8, hidden=512, heads=8, ffn=2048, vocab=4096, max_pos=128),
}

WEIGHT_SEED = 0x0C0117
