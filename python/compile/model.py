"""L2: the OPT-style decoder forward as TP-shardable stage functions.

The rust runtime composes a model forward from four executables (compiled
once per (model, tp, batch, seq) bucket and reused across layers/stages):

  embed      (stage-0 prologue)  ids -> partial hidden        [all-reduce]
  attn_half  (per layer)         hidden -> partial attn out   [all-reduce]
  mlp_half   (per layer)         hidden -> partial mlp out    [all-reduce]
  head       (last-stage epilogue) hidden -> local logit shard [all-gather]

TP conventions (must match rust `model::shard` and `weights.py`):
- q/k/v and fc1 are column-parallel: rank r holds output rows
  [r·n/tp, (r+1)·n/tp); heads split with them.
- out_proj and fc2 are row-parallel: rank r holds input columns; every
  rank adds bias/tp so the sum over ranks reconstructs the bias once.
- embedding is vocab-parallel: rank r embeds ids in its vocab slice and
  contributes zero elsewhere; the (replicated) position embedding is
  scaled by 1/tp for the same sum-once reason.
- residual connections are applied by the *caller* (rust) after each
  all-reduce: x = x + sum_r(partial_r).

The rust side performs the all-reduces (elementwise sums over worker
channel exchanges) and the final all-gather (concat of logit shards).
Python never runs at serving time; these functions exist to be lowered by
`aot.py` into HLO text artifacts.
"""

import jax.numpy as jnp

from .kernels.attention import flash_attention
from .kernels.linear import fused_linear


def layer_norm(x, w, b, eps=1e-5):
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mean) / jnp.sqrt(var + eps) * w + b


def embed_stage(ids, vocab_start, embed_tokens_shard, embed_positions, *, tp: int):
    """Vocab-parallel embedding partial.

    Args:
      ids: (B, S) int32.
      vocab_start: scalar int32 — first vocab row owned by this rank.
      embed_tokens_shard: (vocab/tp, h).
      embed_positions: (max_pos+2, h) replicated.

    Returns:
      (B, S, h) partial — sum over ranks gives tok_embed + pos_embed.
    """
    shard_rows = embed_tokens_shard.shape[0]
    s = ids.shape[1]
    local = ids - vocab_start
    in_range = (local >= 0) & (local < shard_rows)
    clipped = jnp.clip(local, 0, shard_rows - 1)
    tok = embed_tokens_shard[clipped] * in_range[..., None].astype(jnp.float32)
    pos = embed_positions[2 : s + 2]  # OPT's +2 position offset
    return tok + pos[None, :, :] / float(tp)


def attn_half(
    hidden,
    ln_w,
    ln_b,
    q_w,
    q_b,
    k_w,
    k_b,
    v_w,
    v_b,
    o_w,
    o_b,
    *,
    heads_local: int,
    tp: int,
):
    """Pre-LN attention half-layer, TP partial output.

    hidden: (B, S, h). q_w/k_w/v_w: (h/tp, h); o_w: (h, h/tp).
    Returns the partial attention output (B, S, h); caller all-reduces and
    adds the residual.
    """
    b, s, h = hidden.shape
    d = q_w.shape[0] // heads_local
    x = layer_norm(hidden, ln_w, ln_b)
    x2 = x.reshape(b * s, h)
    q = x2 @ q_w.T + q_b
    k = x2 @ k_w.T + k_b
    v = x2 @ v_w.T + v_b

    def split(t):  # (B*S, h/tp) -> (B*heads_local, S, d)
        return (
            t.reshape(b, s, heads_local, d).transpose(0, 2, 1, 3).reshape(b * heads_local, s, d)
        )

    attn = flash_attention(split(q), split(k), split(v))
    attn = attn.reshape(b, heads_local, s, d).transpose(0, 2, 1, 3).reshape(b * s, heads_local * d)
    # Row-parallel out_proj: bias contributed once across ranks.
    out = attn @ o_w.T + o_b / float(tp)
    return out.reshape(b, s, h)


def mlp_half(hidden, ln_w, ln_b, fc1_w, fc1_b, fc2_w, fc2_b, *, tp: int):
    """Pre-LN MLP half-layer (ReLU, as in OPT), TP partial output.

    fc1_w: (f/tp, h) column-parallel — computed with the fused Pallas
    linear kernel (the L1 hot spot); fc2_w: (h, f/tp) row-parallel.
    """
    b, s, h = hidden.shape
    x = layer_norm(hidden, ln_w, ln_b).reshape(b * s, h)
    a = fused_linear(x, fc1_w, fc1_b, activation="relu")
    out = a @ fc2_w.T + fc2_b / float(tp)
    return out.reshape(b, s, h)


def head_stage(hidden, lnf_w, lnf_b, lm_head_shard):
    """Final layer norm + vocab-parallel logits.

    lm_head_shard: (vocab/tp, h) — this rank's logit rows. The caller
    all-gathers (concatenates) shards into the full vocab.
    """
    b, s, h = hidden.shape
    x = layer_norm(hidden, lnf_w, lnf_b)
    return x.reshape(b * s, h) @ lm_head_shard.T


# ---------------------------------------------------------------------------
# Sharded-pipeline emulation (used by tests and aot golden generation; the
# rust runtime performs exactly these reductions with worker channels).
# ---------------------------------------------------------------------------

def forward_sharded(ids, weights, cfg, tp: int):
    """Run the full forward by composing stage functions across tp ranks
    with explicit all-reduces, mirroring the rust execution plan."""
    from .weights import shard_column, shard_row

    b, s = ids.shape
    vocab = cfg["vocab"]
    heads = cfg["heads"]
    assert heads % tp == 0 and vocab % tp == 0

    # Embedding.
    partials = []
    for r in range(tp):
        shard = shard_column(weights["decoder.embed_tokens.weight"], tp, r)
        start = jnp.int32(r * (vocab // tp))
        partials.append(
            embed_stage(ids, start, shard, weights["decoder.embed_positions.weight"], tp=tp)
        )
    x = sum(partials)

    for l in range(cfg["layers"]):
        p = f"decoder.layers.{l}"
        partials = []
        for r in range(tp):
            partials.append(
                attn_half(
                    x,
                    weights[f"{p}.self_attn_layer_norm.weight"],
                    weights[f"{p}.self_attn_layer_norm.bias"],
                    shard_column(weights[f"{p}.self_attn.q_proj.weight"], tp, r),
                    shard_column(weights[f"{p}.self_attn.q_proj.bias"], tp, r),
                    shard_column(weights[f"{p}.self_attn.k_proj.weight"], tp, r),
                    shard_column(weights[f"{p}.self_attn.k_proj.bias"], tp, r),
                    shard_column(weights[f"{p}.self_attn.v_proj.weight"], tp, r),
                    shard_column(weights[f"{p}.self_attn.v_proj.bias"], tp, r),
                    shard_row(weights[f"{p}.self_attn.out_proj.weight"], tp, r),
                    weights[f"{p}.self_attn.out_proj.bias"],
                    heads_local=heads // tp,
                    tp=tp,
                )
            )
        x = x + sum(partials)
        partials = []
        for r in range(tp):
            partials.append(
                mlp_half(
                    x,
                    weights[f"{p}.final_layer_norm.weight"],
                    weights[f"{p}.final_layer_norm.bias"],
                    shard_column(weights[f"{p}.fc1.weight"], tp, r),
                    shard_column(weights[f"{p}.fc1.bias"], tp, r),
                    shard_row(weights[f"{p}.fc2.weight"], tp, r),
                    weights[f"{p}.fc2.bias"],
                    tp=tp,
                )
            )
        x = x + sum(partials)

    logit_shards = []
    for r in range(tp):
        lm = shard_column(weights["decoder.embed_tokens.weight"], tp, r)
        logit_shards.append(
            head_stage(
                x,
                weights["decoder.final_layer_norm.weight"],
                weights["decoder.final_layer_norm.bias"],
                lm,
            )
        )
    logits = jnp.concatenate(logit_shards, axis=-1)  # all-gather
    return logits.reshape(b, s, vocab)
