"""L1: fused causal flash-attention as a Pallas kernel.

The paper's serving hot spot is the transformer forward pass; on the
CUDA testbed this is cuBLAS + fused attention kernels. Per the hardware
adaptation rule we do not port CUDA idioms — the kernel is written
TPU-style:

- the grid iterates (batch·heads, query blocks); each program owns a
  (block_q × head_dim) query tile in VMEM,
- K/V stream through VMEM in (block_k × head_dim) tiles with an online
  (running max / running sum) softmax so the full S×S score matrix never
  materializes — the flash-attention recurrence,
- both matmuls (q·kᵀ and p·v) are shaped for the 128×128 MXU; block sizes
  are clamped to the sequence length so small serving shapes still work.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom calls, so kernels lower to plain HLO for execution and the Mosaic
path is compile-only. VMEM footprint / MXU utilization are estimated
analytically in EXPERIMENTS.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1.0e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, block_k: int, seq_len: int, scale: float):
    """One (bh, q-block) program: online-softmax over K/V tiles."""
    block_q = q_ref.shape[1]
    head_dim = q_ref.shape[2]
    q_block_idx = pl.program_id(1)
    q = q_ref[0, :, :] * scale  # (block_q, d)

    # Running statistics for the online softmax.
    m = jnp.full((block_q,), NEG_INF, dtype=jnp.float32)
    l = jnp.zeros((block_q,), dtype=jnp.float32)
    acc = jnp.zeros((block_q, head_dim), dtype=jnp.float32)

    q_pos = q_block_idx * block_q + jax.lax.iota(jnp.int32, block_q)  # global q rows

    num_k_blocks = (seq_len + block_k - 1) // block_k
    for kb in range(num_k_blocks):  # static unroll: shapes are compile-time
        k_tile = k_ref[0, kb * block_k : (kb + 1) * block_k, :]  # (block_k, d)
        v_tile = v_ref[0, kb * block_k : (kb + 1) * block_k, :]
        k_pos = kb * block_k + jax.lax.iota(jnp.int32, k_tile.shape[0])

        s = jnp.dot(q, k_tile.T, preferred_element_type=jnp.float32)  # (bq, bk)
        causal = q_pos[:, None] >= k_pos[None, :]
        s = jnp.where(causal, s, NEG_INF)

        m_new = jnp.maximum(m, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        correction = jnp.exp(m - m_new)
        l = l * correction + jnp.sum(p, axis=1)
        acc = acc * correction[:, None] + jnp.dot(
            p, v_tile, preferred_element_type=jnp.float32
        )
        m = m_new

    # Causality guarantees every row attends at least to itself: l > 0.
    o_ref[0, :, :] = acc / l[:, None]


def flash_attention(q, k, v, *, block_q: int = 16, block_k: int = 16, interpret: bool = True):
    """Causal self-attention.

    Args:
      q, k, v: float32 ``(batch_heads, seq, head_dim)``.
      block_q / block_k: VMEM tile sizes (clamped to ``seq``).
      interpret: must stay True for CPU-PJRT execution (see module doc).

    Returns:
      ``(batch_heads, seq, head_dim)`` attention output.
    """
    bh, seq, d = q.shape
    assert k.shape == (bh, seq, d) and v.shape == (bh, seq, d)
    block_q = max(1, min(block_q, seq))
    block_k = max(1, min(block_k, seq))
    num_q_blocks = (seq + block_q - 1) // block_q
    if seq % block_q != 0:
        # Keep the kernel simple: require exact q tiling (serving buckets
        # are powers of two; hypothesis sweeps confirm the constraint).
        block_q = seq
        num_q_blocks = 1

    scale = 1.0 / (d ** 0.5)
    kernel = functools.partial(
        _attn_kernel, block_k=block_k, seq_len=seq, scale=scale
    )
    return pl.pallas_call(
        kernel,
        grid=(bh, num_q_blocks),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, seq, d), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, seq, d), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, seq, d), jnp.float32),
        interpret=interpret,
    )(q, k, v)


def vmem_footprint_bytes(seq: int, head_dim: int, block_q: int = 16, block_k: int = 16) -> int:
    """Analytic VMEM estimate per program (EXPERIMENTS.md §Perf): the query
    tile, one K/V tile pair, the accumulator, and softmax statistics."""
    block_q = min(block_q, seq)
    block_k = min(block_k, seq)
    f = 4  # f32
    q_tile = block_q * head_dim * f
    kv_tiles = 2 * block_k * head_dim * f
    acc = block_q * head_dim * f
    stats = 2 * block_q * f
    scores = block_q * block_k * f
    return q_tile + kv_tiles + acc + stats + scores
