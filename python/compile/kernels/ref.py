"""Pure-jnp oracles for the Pallas kernels and the full model forward.

These are the correctness anchors: pytest/hypothesis compare every kernel
against its oracle across shapes, and `model.py`'s sharded stage pipeline
is compared against `ref_opt_forward` (the unsharded reference) both in
python tests and — through the golden vectors in the artifact manifest —
in the rust runtime's integration tests.
"""

import jax
import jax.numpy as jnp


def ref_attention(q, k, v):
    """Causal attention, direct softmax. q/k/v: (BH, S, D) f32."""
    _, seq, d = q.shape
    scale = 1.0 / (d ** 0.5)
    s = jnp.einsum("bqd,bkd->bqk", q, k) * scale
    mask = jnp.tril(jnp.ones((seq, seq), dtype=bool))
    s = jnp.where(mask[None, :, :], s, -1.0e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v)


def ref_linear(x, w, b, activation="none"):
    """act(x @ w.T + b). x: (M,K), w: (N,K), b: (N,)."""
    y = x @ w.T + b
    if activation == "relu":
        y = jnp.maximum(y, 0.0)
    elif activation == "gelu":
        y = jax.nn.gelu(y)
    return y


def ref_layer_norm(x, w, b, eps=1e-5):
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mean) / jnp.sqrt(var + eps) * w + b


def ref_opt_forward(ids, weights, cfg):
    """Unsharded OPT-style decoder forward.

    Args:
      ids: (B, S) int32 token ids.
      weights: dict tensor-name -> array (full, unsharded), names as in
        rust `model::spec::ModelSpec::tensors`.
      cfg: dict with layers/hidden/heads/ffn/vocab/max_pos.

    Returns:
      (B, S, vocab) logits.
    """
    b, s = ids.shape
    h = cfg["hidden"]
    heads = cfg["heads"]
    d = h // heads

    tok = weights["decoder.embed_tokens.weight"][ids]  # (B,S,h)
    pos = weights["decoder.embed_positions.weight"][2 : s + 2]  # OPT +2 offset
    x = tok + pos[None, :, :]

    for l in range(cfg["layers"]):
        p = f"decoder.layers.{l}"
        # Attention block (pre-LN).
        y = ref_layer_norm(
            x, weights[f"{p}.self_attn_layer_norm.weight"], weights[f"{p}.self_attn_layer_norm.bias"]
        )
        q = y @ weights[f"{p}.self_attn.q_proj.weight"].T + weights[f"{p}.self_attn.q_proj.bias"]
        k = y @ weights[f"{p}.self_attn.k_proj.weight"].T + weights[f"{p}.self_attn.k_proj.bias"]
        v = y @ weights[f"{p}.self_attn.v_proj.weight"].T + weights[f"{p}.self_attn.v_proj.bias"]
        # (B,S,h) -> (B*heads, S, d)
        split = lambda t: t.reshape(b, s, heads, d).transpose(0, 2, 1, 3).reshape(b * heads, s, d)
        attn = ref_attention(split(q), split(k), split(v))
        attn = attn.reshape(b, heads, s, d).transpose(0, 2, 1, 3).reshape(b, s, h)
        attn = attn @ weights[f"{p}.self_attn.out_proj.weight"].T + weights[f"{p}.self_attn.out_proj.bias"]
        x = x + attn
        # MLP block (pre-LN, ReLU as in OPT).
        y = ref_layer_norm(
            x, weights[f"{p}.final_layer_norm.weight"], weights[f"{p}.final_layer_norm.bias"]
        )
        a = ref_linear(
            y.reshape(b * s, h), weights[f"{p}.fc1.weight"], weights[f"{p}.fc1.bias"], "relu"
        )
        m = ref_linear(a, weights[f"{p}.fc2.weight"], weights[f"{p}.fc2.bias"])
        x = x + m.reshape(b, s, h)

    x = ref_layer_norm(
        x, weights["decoder.final_layer_norm.weight"], weights["decoder.final_layer_norm.bias"]
    )
    # Tied lm_head.
    return x @ weights["decoder.embed_tokens.weight"].T
