"""L1: fused linear (+bias +activation) as a Pallas kernel.

Computes ``act(x @ w.T + b)`` with (M, N, K) tiling:

- grid = (M/bm, N/bn, K/bk); the K axis is the innermost (fastest) grid
  dimension, so each (i, j) output tile is visited K/bk times and the
  partial products accumulate in the output ref — the canonical Pallas
  matmul pattern (grid-carried accumulation maps to double-buffered K
  streaming through VMEM on real hardware),
- tiles default to 128 (clamped to the problem) to line up with the
  128×128 MXU systolic array,
- bias add + activation are fused into the final K step, saving an HBM
  round-trip for the activation tensor.

Used by the L2 model for the MLP fc1 (ReLU, as in OPT). interpret=True
for CPU-PJRT execution (see attention.py module doc).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _linear_kernel(x_ref, w_ref, b_ref, o_ref, *, num_k_blocks: int, activation: str):
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x_tile = x_ref[...]  # (bm, bk)
    w_tile = w_ref[...]  # (bn, bk)
    o_ref[...] += jnp.dot(x_tile, w_tile.T, preferred_element_type=jnp.float32)

    @pl.when(k_idx == num_k_blocks - 1)
    def _finish():
        y = o_ref[...] + b_ref[...][None, :]
        if activation == "relu":
            y = jnp.maximum(y, 0.0)
        elif activation == "gelu":
            y = jax.nn.gelu(y)
        elif activation != "none":
            raise ValueError(f"unknown activation {activation!r}")
        o_ref[...] = y


def fused_linear(
    x,
    w,
    b,
    *,
    activation: str = "none",
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    interpret: bool = True,
):
    """``act(x @ w.T + b)``.

    Args:
      x: ``(M, K)`` float32.
      w: ``(N, K)`` float32 (PyTorch Linear layout: out_features first).
      b: ``(N,)`` float32.
      activation: ``"none" | "relu" | "gelu"``.

    Returns:
      ``(M, N)`` float32.
    """
    m, k = x.shape
    n, k2 = w.shape
    assert k == k2, f"inner dims mismatch: {k} vs {k2}"
    assert b.shape == (n,)

    bm = max(1, min(block_m, m))
    bn = max(1, min(block_n, n))
    bk = max(1, min(block_k, k))
    # Require exact tiling (shapes in this repo are powers of two); fall
    # back to untiled dims otherwise so arbitrary hypothesis shapes work.
    if m % bm != 0:
        bm = m
    if n % bn != 0:
        bn = n
    if k % bk != 0:
        bk = k
    num_k_blocks = k // bk

    kernel = functools.partial(
        _linear_kernel, num_k_blocks=num_k_blocks, activation=activation
    )
    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn, num_k_blocks),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bn, bk), lambda i, j, kk: (j, kk)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, w, b)


def vmem_footprint_bytes(block_m=128, block_n=128, block_k=128) -> int:
    """Analytic VMEM estimate per program: one x tile, one w tile, the
    accumulator tile, and the bias slice (EXPERIMENTS.md §Perf)."""
    f = 4
    return (block_m * block_k + block_n * block_k + block_m * block_n + block_n) * f


def mxu_utilization(m: int, n: int, k: int, block_m=128, block_n=128, block_k=128) -> float:
    """Fraction of MXU tile slots doing useful MACs (1.0 when every tile
    dimension divides 128)."""
    bm = min(block_m, m)
    bn = min(block_n, n)
    bk = min(block_k, k)
    eff = lambda b: min(b, 128) / 128.0
    return eff(bm) * eff(bn) * eff(bk)
