//! Compile-only stub of the `xla` crate (xla-rs style PJRT bindings).
//!
//! The real-mode execution path (`runtime::exec`, `serving`) is written
//! against the PJRT CPU client of the `xla` crate, which links the XLA
//! C++ runtime and is not available in this offline build environment.
//! This stub preserves the exact API surface the repo uses so the whole
//! workspace builds and the simulator/test suite runs; any attempt to
//! actually execute a computation returns a descriptive error.
//!
//! Real-mode tests and examples gate on the artifact manifest
//! (`artifacts/manifest.json`, produced by `make artifacts`) and skip
//! when it is absent, so a stubbed runtime never reaches `execute_b`.
//! Swapping in the real binding is a Cargo.toml one-liner (point the
//! `xla` path dependency at the actual crate); no source changes needed.

use std::fmt;
use std::path::Path;

/// Error type mirroring xla-rs: a message string.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn stub_err(what: &str) -> Error {
    Error(format!(
        "{what} is unavailable: the vendored `xla` crate is a compile-only \
         stub (run against the real PJRT binding for real-mode execution)"
    ))
}

/// Element types accepted by buffer upload / literal download.
pub trait NativeType: Copy + 'static {}
impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u32 {}

/// Parsed HLO module (stub holds nothing).
pub struct HloModuleProto;

impl HloModuleProto {
    /// Parse an HLO text file. The stub validates that the file exists
    /// and is readable, which keeps artifact plumbing errors accurate.
    pub fn from_text_file(path: impl AsRef<Path>) -> Result<HloModuleProto> {
        let path = path.as_ref();
        std::fs::metadata(path)
            .map_err(|e| Error(format!("reading {}: {e}", path.display())))?;
        Ok(HloModuleProto)
    }
}

/// An XLA computation built from a proto.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device buffer handle (stub holds nothing).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(stub_err("PjRtBuffer::to_literal_sync"))
    }
}

/// Host literal (stub holds nothing).
pub struct Literal;

impl Literal {
    pub fn to_tuple1(self) -> Result<Literal> {
        Err(stub_err("Literal::to_tuple1"))
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(stub_err("Literal::to_vec"))
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute_b<B: std::borrow::Borrow<PjRtBuffer>>(
        &self,
        _args: &[B],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(stub_err("PjRtLoadedExecutable::execute_b"))
    }
}

/// PJRT client handle.
pub struct PjRtClient;

impl PjRtClient {
    /// CPU client. Construction succeeds so environment probes
    /// (`computron info`) and launch-time validation still run; only
    /// compilation/execution is stubbed out.
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient)
    }

    pub fn platform_name(&self) -> String {
        "cpu-stub".to_string()
    }

    pub fn device_count(&self) -> usize {
        1
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(stub_err("PjRtClient::compile"))
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Err(stub_err("PjRtClient::buffer_from_host_buffer"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_constructs_but_cannot_compile() {
        let c = PjRtClient::cpu().unwrap();
        assert_eq!(c.platform_name(), "cpu-stub");
        assert_eq!(c.device_count(), 1);
        let comp = XlaComputation::from_proto(&HloModuleProto);
        assert!(c.compile(&comp).is_err());
        assert!(c.buffer_from_host_buffer::<f32>(&[1.0], &[1], None).is_err());
    }

    #[test]
    fn missing_hlo_file_reports_path() {
        let err = HloModuleProto::from_text_file("/nonexistent/stage.hlo").unwrap_err();
        assert!(err.to_string().contains("/nonexistent/stage.hlo"));
    }

    #[test]
    fn execute_reports_stub() {
        let exe = PjRtLoadedExecutable;
        let args: Vec<&PjRtBuffer> = Vec::new();
        let err = exe.execute_b::<&PjRtBuffer>(&args).unwrap_err();
        assert!(err.to_string().contains("stub"));
    }
}
