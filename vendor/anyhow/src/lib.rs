//! Offline vendored subset of the `anyhow` API.
//!
//! The build environment has no network access to crates.io, so this
//! path crate provides the (small) slice of anyhow that the repo uses:
//! `Error`, `Result`, the `anyhow!` / `bail!` / `ensure!` macros, and the
//! `Context` extension trait. Semantics match upstream for this subset:
//!
//! - `Error` wraps a message chain and converts (via a blanket `From`)
//!   from any `std::error::Error + Send + Sync + 'static`;
//! - like upstream, `Error` deliberately does NOT implement
//!   `std::error::Error` itself — that is what makes the blanket `From`
//!   coherent;
//! - `.context(..)` / `.with_context(..)` prepend a message, and `{:#}`
//!   formatting shows the full chain (here: the same string, since the
//!   chain is pre-rendered at wrap time).

use std::fmt;

/// Error type: a rendered message (chain flattened at construction).
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string() }
    }

    /// Prepend a context message (the `Context` trait calls this).
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: format!("{context}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error { msg: e.to_string() }
    }
}

/// `anyhow::Result<T>` — alias with our `Error` as the default error.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{context}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f().to_string()))
    }
}

/// Construct an [`Error`] from a format string or any displayable value.
#[macro_export]
macro_rules! anyhow {
    ($fmt:literal $(, $arg:expr)* $(,)?) => {
        $crate::Error::msg(format!($fmt $(, $arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($tt:tt)*) => {
        return Err($crate::anyhow!($($tt)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(format!(
                "Condition failed: `{}`",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($tt:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($tt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert!(e.to_string().contains("missing"));
    }

    #[test]
    fn macros_format() {
        let name = "x";
        let e = anyhow!("bad flag --{name}: {}", 42);
        assert_eq!(e.to_string(), "bad flag --x: 42");
        let e2 = anyhow!(String::from("plain"));
        assert_eq!(e2.to_string(), "plain");
    }

    #[test]
    fn bail_and_ensure() {
        fn f(ok: bool) -> Result<u32> {
            ensure!(ok, "not ok: {}", 7);
            Ok(1)
        }
        fn g() -> Result<u32> {
            bail!("stop");
        }
        fn h(v: usize) -> Result<()> {
            ensure!(v > 2);
            Ok(())
        }
        assert_eq!(f(true).unwrap(), 1);
        assert_eq!(f(false).unwrap_err().to_string(), "not ok: 7");
        assert_eq!(g().unwrap_err().to_string(), "stop");
        assert!(h(1).unwrap_err().to_string().contains("v > 2"));
    }

    #[test]
    fn context_prepends() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.with_context(|| format!("loading {}", "f.json")).unwrap_err();
        assert!(e.to_string().starts_with("loading f.json: "));
        let r2: std::result::Result<(), std::io::Error> = Err(io_err());
        let e2 = r2.context("ctx").unwrap_err();
        assert!(e2.to_string().starts_with("ctx: "));
        assert_eq!(format!("{e2:#}"), e2.to_string());
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        assert_eq!(v.context("empty").unwrap_err().to_string(), "empty");
    }
}
