"""Unit tests for the perf-smoke diff logic (scripts/check_bench.py).

Ports the old test_check_perf_simcore.py suite onto the generalized
gate and adds coverage for the fleet_scale / planner_suite indexers,
per-metric tolerances, and unknown-bench handling.

Run with either harness:
    python3 -m unittest discover -s scripts
    python -m pytest scripts/
"""

import json
import os
import sys
import tempfile
import unittest

sys.path.insert(0, os.path.dirname(__file__))

import check_bench as cb


def report(calibrated=True, fast=True, e2e=(), churn=(), parallel=(), ratios=None):
    doc = {
        "bench": "perf_simcore",
        "calibrated": calibrated,
        "fast": fast,
        "e2e": [
            {
                "scenario": s,
                "groups": g,
                "backend": b,
                "events_per_sec": rate,
            }
            for (s, g, b, rate) in e2e
        ],
        "queue_churn": [
            {"backend": b, "pending": p, "events_per_sec": rate}
            for (b, p, rate) in churn
        ],
        "parallel": [
            {
                "scenario": s,
                "groups": g,
                "exec": e,
                "events_per_sec": rate,
            }
            for (s, g, e, rate) in parallel
        ],
    }
    doc.update(ratios or {})
    return doc


def fleet_report(calibrated=True, fast=True, cells=(), totals=None):
    doc = {
        "bench": "fleet_scale",
        "calibrated": calibrated,
        "fast": fast,
        "cells": [
            {
                "models": n,
                "dedup": d,
                "policy": p,
                "goodput": goodput,
                "host_hit_rate": hit,
            }
            for (n, d, p, goodput, hit) in cells
        ],
    }
    doc.update(totals or {})
    return doc


def planner_report(calibrated=True, fast=True, arms=(), cells=(), speedup=0):
    return {
        "experiment": "planner_suite",
        "calibrated": calibrated,
        "fast": fast,
        "scoring_workers": [
            {"workers": w, "candidates_per_sec": rate} for (w, rate) in arms
        ],
        "planner_speedup_workers4": speedup,
        "cells": [
            {
                "scenario": s,
                "outcomes": [
                    {"candidate": c, "goodput": g} for (c, g) in outcomes
                ],
            }
            for (s, outcomes) in cells
        ],
    }


class IndexCellsTest(unittest.TestCase):
    def test_perf_simcore_keys_cover_all_sections(self):
        doc = report(
            e2e=[("zipf", 4, "calendar", 100.0)],
            churn=[("heap", 10000, 50.0)],
            parallel=[("zipf-dedicated", 4, "parallel", 200.0)],
            ratios={"parallel_speedup_g4": 2.0},
        )
        cells = cb.index_cells(doc)
        self.assertEqual(cells[("e2e", "zipf", 4, "calendar")], (100.0, 0.20))
        self.assertEqual(cells[("churn", "heap", 10000)], (50.0, 0.20))
        self.assertEqual(
            cells[("parallel", "zipf-dedicated", 4, "parallel")], (200.0, 0.20)
        )
        self.assertEqual(
            cells[("ratio", "parallel_speedup_g4")], (2.0, cb.RATIO_TOLERANCE)
        )
        # Unset ratios index as 0 (placeholder) rather than KeyError.
        self.assertEqual(
            cells[("ratio", "e2e_speedup_zipf_g4")], (0, cb.RATIO_TOLERANCE)
        )

    def test_missing_sections_yield_only_ratio_placeholders(self):
        cells = cb.index_cells({"bench": "perf_simcore"})
        self.assertTrue(all(key[0] == "ratio" for key in cells))
        self.assertTrue(all(cb._split(v)[0] == 0 for v in cells.values()))

    def test_fleet_scale_keys(self):
        doc = fleet_report(
            cells=[(1000, True, "weighted-cost", 40.0, 0.9)],
            totals={"dedup_goodput": 40.0, "full_form_goodput": 30.0},
        )
        cells = cb.index_cells(doc)
        self.assertEqual(
            cells[("goodput", 1000, True, "weighted-cost")], (40.0, 0.20)
        )
        self.assertEqual(
            cells[("hit_rate", 1000, True, "weighted-cost")],
            (0.9, cb.HIT_RATE_TOLERANCE),
        )
        self.assertEqual(cells[("total", "dedup_goodput")], (40.0, 0.20))
        self.assertEqual(cells[("total", "full_form_goodput")], (30.0, 0.20))

    def test_planner_suite_keys(self):
        doc = planner_report(
            arms=[(1, 10.0), (4, 35.0)],
            cells=[("zipf", [("planner", 50.0), ("groups_2x2 preset", 40.0)])],
            speedup=3.5,
        )
        cells = cb.index_cells(doc)
        self.assertEqual(cells[("scoring", 1)], (10.0, cb.RATIO_TOLERANCE))
        self.assertEqual(cells[("scoring", 4)], (35.0, cb.RATIO_TOLERANCE))
        self.assertEqual(
            cells[("ratio", "planner_speedup_workers4")],
            (3.5, cb.RATIO_TOLERANCE),
        )
        self.assertEqual(cells[("goodput", "zipf", "planner")], (50.0, 0.20))
        self.assertEqual(
            cells[("goodput", "zipf", "groups_2x2 preset")], (40.0, 0.20)
        )

    def test_unknown_bench_raises(self):
        with self.assertRaises(ValueError):
            cb.index_cells({"bench": "mystery"})
        with self.assertRaises(ValueError):
            cb.index_cells({})


class CompareCellsTest(unittest.TestCase):
    def test_regression_beyond_tolerance_is_flagged(self):
        base = {("churn", "calendar", 10000): 100.0}
        new = {("churn", "calendar", 10000): 79.0}
        lines, regressions, compared = cb.compare_cells(base, new)
        self.assertEqual(compared, 1)
        self.assertEqual(len(regressions), 1)
        key, base_value, new_value, ratio = regressions[0]
        self.assertEqual(key, ("churn", "calendar", 10000))
        self.assertAlmostEqual(ratio, 0.79)
        self.assertIn("REGRESSION", lines[0])

    def test_exact_tolerance_boundary_passes(self):
        # ratio == 1 - tolerance is NOT a regression (strictly below fails).
        base = {("churn", "heap", 10000): 100.0}
        new = {("churn", "heap", 10000): 80.0}
        _, regressions, compared = cb.compare_cells(base, new)
        self.assertEqual(compared, 1)
        self.assertEqual(regressions, [])

    def test_improvement_passes(self):
        base = {("e2e", "zipf", 1, "calendar"): 100.0}
        new = {("e2e", "zipf", 1, "calendar"): 150.0}
        _, regressions, _ = cb.compare_cells(base, new)
        self.assertEqual(regressions, [])

    def test_unmeasured_baseline_cells_are_skipped(self):
        # value <= 0 means "not yet measured" (bootstrap rows).
        base = {("churn", "calendar", 10000): 0}
        new = {("churn", "calendar", 10000): 123.0}
        lines, regressions, compared = cb.compare_cells(base, new)
        self.assertEqual((lines, regressions, compared), ([], [], 0))

    def test_cells_missing_from_new_run_are_skipped(self):
        base = {("e2e", "zipf", 4, "heap"): 100.0}
        _, regressions, compared = cb.compare_cells(base, {})
        self.assertEqual((regressions, compared), ([], 0))

    def test_per_metric_tolerance_from_baseline_entry(self):
        # A 21% drop regresses a 20%-tolerance metric but not a 25% one.
        base = {("ratio", "x"): (100.0, 0.25), ("e2e", "y"): (100.0, 0.20)}
        new = {("ratio", "x"): 79.0, ("e2e", "y"): 79.0}
        _, regressions, compared = cb.compare_cells(base, new)
        self.assertEqual(compared, 2)
        self.assertEqual([key for key, *_ in regressions], [("e2e", "y")])


class AdvisoryReasonsTest(unittest.TestCase):
    def test_uncalibrated_baseline_is_advisory(self):
        reasons = cb.advisory_reasons(report(calibrated=False), report())
        self.assertTrue(any("uncalibrated" in r for r in reasons))

    def test_mode_mismatch_is_advisory(self):
        reasons = cb.advisory_reasons(report(fast=True), report(fast=False))
        self.assertTrue(any("mode mismatch" in r for r in reasons))

    def test_calibrated_same_mode_binds(self):
        self.assertEqual(cb.advisory_reasons(report(), report()), [])


class CalibrateTest(unittest.TestCase):
    def test_calibrate_flips_flag_and_keeps_cells(self):
        fresh = report(
            calibrated=False,
            e2e=[("zipf", 4, "calendar", 321.0)],
            churn=[("heap", 10000, 50.0)],
        )
        doc = cb.calibrate(fresh)
        self.assertTrue(doc["calibrated"])
        self.assertEqual(cb.index_cells(doc), cb.index_cells(fresh))
        # The input document is not mutated.
        self.assertFalse(fresh["calibrated"])


class MainExitCodeTest(unittest.TestCase):
    def write(self, doc):
        f = tempfile.NamedTemporaryFile(
            "w", suffix=".json", delete=False, dir=self.dir.name
        )
        json.dump(doc, f)
        f.close()
        return f.name

    def setUp(self):
        self.dir = tempfile.TemporaryDirectory()
        self.addCleanup(self.dir.cleanup)

    def test_binding_regression_fails(self):
        base = self.write(report(churn=[("heap", 10000, 100.0)]))
        new = self.write(report(churn=[("heap", 10000, 10.0)]))
        self.assertEqual(cb.main(["prog", base, new]), 1)

    def test_advisory_regression_passes(self):
        base = self.write(
            report(calibrated=False, churn=[("heap", 10000, 100.0)])
        )
        new = self.write(report(churn=[("heap", 10000, 10.0)]))
        self.assertEqual(cb.main(["prog", base, new]), 0)

    def test_clean_run_passes(self):
        base = self.write(report(churn=[("heap", 10000, 100.0)]))
        new = self.write(report(churn=[("heap", 10000, 101.0)]))
        self.assertEqual(cb.main(["prog", base, new]), 0)

    def test_fleet_scale_binding_regression_fails(self):
        base = self.write(
            fleet_report(cells=[(1000, True, "weighted-cost", 100.0, 0.9)])
        )
        new = self.write(
            fleet_report(cells=[(1000, True, "weighted-cost", 10.0, 0.9)])
        )
        self.assertEqual(cb.main(["prog", base, new]), 1)

    def test_planner_suite_binding_regression_fails(self):
        base = self.write(planner_report(arms=[(4, 100.0)], speedup=3.5))
        new = self.write(planner_report(arms=[(4, 10.0)], speedup=3.5))
        self.assertEqual(cb.main(["prog", base, new]), 1)

    def test_bench_mismatch_is_a_warning_not_a_failure(self):
        base = self.write(report(churn=[("heap", 10000, 100.0)]))
        new = self.write(fleet_report())
        self.assertEqual(cb.main(["prog", base, new]), 0)

    def test_unknown_bench_is_a_warning_not_a_failure(self):
        base = self.write({"bench": "mystery", "calibrated": True})
        new = self.write({"bench": "mystery", "calibrated": True})
        self.assertEqual(cb.main(["prog", base, new]), 0)

    def test_calibrate_writes_calibrated_baseline(self):
        fresh = self.write(
            report(calibrated=False, churn=[("heap", 10000, 100.0)])
        )
        out = os.path.join(self.dir.name, "baseline.json")
        self.assertEqual(cb.main(["prog", "--calibrate", fresh, out]), 0)
        with open(out) as f:
            doc = json.load(f)
        self.assertTrue(doc["calibrated"])
        self.assertEqual(
            cb.index_cells(doc)[("churn", "heap", 10000)], (100.0, 0.20)
        )


if __name__ == "__main__":
    unittest.main()
