"""Unit tests for the perf-smoke diff logic (scripts/check_perf_simcore.py).

Run with either harness:
    python3 -m unittest discover -s scripts
    python -m pytest scripts/
"""

import json
import os
import sys
import tempfile
import unittest

sys.path.insert(0, os.path.dirname(__file__))

import check_perf_simcore as cps


def report(calibrated=True, fast=True, e2e=(), churn=()):
    return {
        "bench": "perf_simcore",
        "calibrated": calibrated,
        "fast": fast,
        "e2e": [
            {
                "scenario": s,
                "groups": g,
                "backend": b,
                "events_per_sec": rate,
            }
            for (s, g, b, rate) in e2e
        ],
        "queue_churn": [
            {"backend": b, "pending": p, "events_per_sec": rate}
            for (b, p, rate) in churn
        ],
    }


class IndexCellsTest(unittest.TestCase):
    def test_keys_cover_both_sections(self):
        doc = report(
            e2e=[("zipf", 4, "calendar", 100.0)],
            churn=[("heap", 10000, 50.0)],
        )
        cells = cps.index_cells(doc)
        self.assertEqual(
            cells,
            {
                ("e2e", "zipf", 4, "calendar"): 100.0,
                ("churn", "heap", 10000): 50.0,
            },
        )

    def test_missing_sections_yield_empty_index(self):
        self.assertEqual(cps.index_cells({"bench": "perf_simcore"}), {})


class CompareCellsTest(unittest.TestCase):
    def test_regression_beyond_tolerance_is_flagged(self):
        base = {("churn", "calendar", 10000): 100.0}
        new = {("churn", "calendar", 10000): 79.0}
        lines, regressions, compared = cps.compare_cells(base, new)
        self.assertEqual(compared, 1)
        self.assertEqual(len(regressions), 1)
        key, base_rate, new_rate, ratio = regressions[0]
        self.assertEqual(key, ("churn", "calendar", 10000))
        self.assertAlmostEqual(ratio, 0.79)
        self.assertIn("REGRESSION", lines[0])

    def test_exact_tolerance_boundary_passes(self):
        # ratio == 1 - TOLERANCE is NOT a regression (strictly below fails).
        base = {("churn", "heap", 10000): 100.0}
        new = {("churn", "heap", 10000): 80.0}
        _, regressions, compared = cps.compare_cells(base, new)
        self.assertEqual(compared, 1)
        self.assertEqual(regressions, [])

    def test_improvement_passes(self):
        base = {("e2e", "zipf", 1, "calendar"): 100.0}
        new = {("e2e", "zipf", 1, "calendar"): 150.0}
        _, regressions, _ = cps.compare_cells(base, new)
        self.assertEqual(regressions, [])

    def test_unmeasured_baseline_cells_are_skipped(self):
        # events_per_sec <= 0 means "not yet measured" (bootstrap rows).
        base = {("churn", "calendar", 10000): 0}
        new = {("churn", "calendar", 10000): 123.0}
        lines, regressions, compared = cps.compare_cells(base, new)
        self.assertEqual((lines, regressions, compared), ([], [], 0))

    def test_cells_missing_from_new_run_are_skipped(self):
        base = {("e2e", "zipf", 4, "heap"): 100.0}
        _, regressions, compared = cps.compare_cells(base, {})
        self.assertEqual((regressions, compared), ([], 0))

    def test_custom_tolerance(self):
        base = {("churn", "heap", 1): 100.0}
        new = {("churn", "heap", 1): 94.0}
        _, regressions, _ = cps.compare_cells(base, new, tolerance=0.05)
        self.assertEqual(len(regressions), 1)
        _, regressions, _ = cps.compare_cells(base, new, tolerance=0.10)
        self.assertEqual(regressions, [])


class AdvisoryReasonsTest(unittest.TestCase):
    def test_uncalibrated_baseline_is_advisory(self):
        reasons = cps.advisory_reasons(report(calibrated=False), report())
        self.assertTrue(any("uncalibrated" in r for r in reasons))

    def test_mode_mismatch_is_advisory(self):
        reasons = cps.advisory_reasons(report(fast=True), report(fast=False))
        self.assertTrue(any("mode mismatch" in r for r in reasons))

    def test_calibrated_same_mode_binds(self):
        self.assertEqual(cps.advisory_reasons(report(), report()), [])


class CalibrateTest(unittest.TestCase):
    def test_calibrate_flips_flag_and_keeps_cells(self):
        fresh = report(
            calibrated=False,
            e2e=[("zipf", 4, "calendar", 321.0)],
            churn=[("heap", 10000, 50.0)],
        )
        doc = cps.calibrate(fresh)
        self.assertTrue(doc["calibrated"])
        self.assertEqual(cps.index_cells(doc), cps.index_cells(fresh))
        # The input document is not mutated.
        self.assertFalse(fresh["calibrated"])


class MainExitCodeTest(unittest.TestCase):
    def write(self, doc):
        f = tempfile.NamedTemporaryFile(
            "w", suffix=".json", delete=False, dir=self.dir.name
        )
        json.dump(doc, f)
        f.close()
        return f.name

    def setUp(self):
        self.dir = tempfile.TemporaryDirectory()
        self.addCleanup(self.dir.cleanup)

    def test_binding_regression_fails(self):
        base = self.write(report(churn=[("heap", 10000, 100.0)]))
        new = self.write(report(churn=[("heap", 10000, 10.0)]))
        self.assertEqual(cps.main(["prog", base, new]), 1)

    def test_advisory_regression_passes(self):
        base = self.write(
            report(calibrated=False, churn=[("heap", 10000, 100.0)])
        )
        new = self.write(report(churn=[("heap", 10000, 10.0)]))
        self.assertEqual(cps.main(["prog", base, new]), 0)

    def test_clean_run_passes(self):
        base = self.write(report(churn=[("heap", 10000, 100.0)]))
        new = self.write(report(churn=[("heap", 10000, 101.0)]))
        self.assertEqual(cps.main(["prog", base, new]), 0)

    def test_calibrate_writes_calibrated_baseline(self):
        fresh = self.write(
            report(calibrated=False, churn=[("heap", 10000, 100.0)])
        )
        out = os.path.join(self.dir.name, "baseline.json")
        self.assertEqual(cps.main(["prog", "--calibrate", fresh, out]), 0)
        with open(out) as f:
            doc = json.load(f)
        self.assertTrue(doc["calibrated"])
        self.assertEqual(
            cps.index_cells(doc), {("churn", "heap", 10000): 100.0}
        )


if __name__ == "__main__":
    unittest.main()
