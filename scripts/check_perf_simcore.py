#!/usr/bin/env python3
"""CI perf-smoke gate for the simulator core (EXPERIMENTS.md §Perf).

Compares a fresh ``perf_simcore`` run against the committed baseline
``BENCH_perf_simcore.json`` and fails on a >20% events/sec regression in
any comparable cell (same scenario/groups/backend, or same queue-churn
backend/pending size).

Conventions:

- The committed baseline is regenerated on the CI reference machine and
  marked ``"calibrated": true``. A baseline with ``"calibrated": false``
  (bootstrap placeholder, or hand-edited) makes every comparison
  advisory: differences are printed but never fail the job, since the
  numbers were not produced on comparable hardware.
- Fast-mode and full-mode runs are not comparable; a mode mismatch is
  also advisory.

Usage: check_perf_simcore.py <baseline.json> <new.json>
"""

import json
import sys

TOLERANCE = 0.20


def load(path):
    with open(path) as f:
        return json.load(f)


def index_cells(doc):
    cells = {}
    for cell in doc.get("e2e", []):
        key = ("e2e", cell["scenario"], cell["groups"], cell["backend"])
        cells[key] = cell["events_per_sec"]
    for cell in doc.get("queue_churn", []):
        key = ("churn", cell["backend"], cell["pending"])
        cells[key] = cell["events_per_sec"]
    return cells


def main():
    if len(sys.argv) != 3:
        sys.exit(__doc__)
    baseline = load(sys.argv[1])
    new = load(sys.argv[2])

    advisory = []
    if not baseline.get("calibrated", False):
        advisory.append("baseline is uncalibrated (bootstrap placeholder)")
    if baseline.get("fast") != new.get("fast"):
        advisory.append(
            f"mode mismatch: baseline fast={baseline.get('fast')} "
            f"vs new fast={new.get('fast')}"
        )

    base_cells = index_cells(baseline)
    new_cells = index_cells(new)
    regressions = []
    compared = 0
    for key, base_rate in sorted(base_cells.items()):
        if key not in new_cells or base_rate <= 0:
            continue
        compared += 1
        new_rate = new_cells[key]
        ratio = new_rate / base_rate
        marker = ""
        if ratio < 1.0 - TOLERANCE:
            marker = "  << REGRESSION"
            regressions.append((key, base_rate, new_rate, ratio))
        print(
            f"{'/'.join(str(k) for k in key):48s} "
            f"base {base_rate:14.1f}  new {new_rate:14.1f}  "
            f"ratio {ratio:5.2f}{marker}"
        )

    if compared == 0:
        print("WARNING: no comparable cells between baseline and new run")

    if regressions:
        print(
            f"\n{len(regressions)} cell(s) regressed by more than "
            f"{TOLERANCE:.0%} in events/sec."
        )
        if advisory:
            print("ADVISORY ONLY (not failing):")
            for reason in advisory:
                print(f"  - {reason}")
            return 0
        return 1

    print("\nperf_simcore: no events/sec regression beyond tolerance.")
    return 0


if __name__ == "__main__":
    sys.exit(main())
