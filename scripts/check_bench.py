#!/usr/bin/env python3
"""CI perf-smoke gate for the bench JSON artifacts (EXPERIMENTS.md §Perf).

Generalizes the old ``check_perf_simcore.py`` to *any* registered
``BENCH_<name>.json``: compares a fresh run against the committed
baseline and fails on a regression beyond the metric's tolerance in any
comparable cell. The bench is auto-detected from the document's
``bench``/``experiment`` field; registered benches:

- ``perf_simcore`` — events/sec per queue-churn, end-to-end, and
  parallel-executor cell, plus the named speedup ratios
  (``queue_speedup_largest_pending``, ``e2e_speedup_zipf_g4``,
  ``parallel_speedup_g2``, ``parallel_speedup_g4``).
- ``fleet_scale`` — goodput and host hit rate per fleet cell, plus the
  dedup-vs-full-form goodput totals.
- ``planner_suite`` — candidates/sec per scoring-pool arm and
  ``planner_speedup_workers4``, plus per-candidate goodput on every
  planning cell.

Every metric is higher-is-better; each carries its own tolerance
(events/sec and goodput 20%, speedup ratios and candidates/sec 25% —
wall-clock ratios on shared CI runners are noisier, hit rates 10%).

Conventions (unchanged from the perf_simcore-only gate):

- The committed baseline is regenerated on the CI reference machine and
  marked ``"calibrated": true``. A baseline with ``"calibrated": false``
  (bootstrap placeholder, or hand-edited) makes every comparison
  advisory: differences are printed but never fail the job, since the
  numbers were not produced on comparable hardware.
- Fast-mode and full-mode runs are not comparable; a mode mismatch is
  also advisory.
- Cells with a non-positive baseline value mean "not yet measured" and
  are skipped by the diff.

Usage:
    check_bench.py <baseline.json> <new.json>
        Diff a fresh run against the baseline; exit 1 on a binding
        (non-advisory) regression.
    check_bench.py --calibrate <new.json> <baseline-out.json>
        Promote a fresh run to a calibrated baseline: stamps
        ``calibrated: true`` and writes it where the repo expects the
        committed baseline. CI runs this when the committed baseline is
        still the bootstrap placeholder and uploads the result as an
        artifact ready to commit.

The pure helpers (``index_cells``, ``compare_cells``,
``advisory_reasons``, ``calibrate``) are unit-tested by
``scripts/test_check_bench.py`` (run ``python3 -m unittest discover -s
scripts`` or ``python -m pytest scripts/``).
"""

import json
import sys

DEFAULT_TOLERANCE = 0.20
#: Wall-clock ratios and planner scoring rates bounce more on shared CI
#: runners than raw event rates do.
RATIO_TOLERANCE = 0.25
HIT_RATE_TOLERANCE = 0.10


def load(path):
    with open(path) as f:
        return json.load(f)


def _entry(value, tolerance):
    return (value, tolerance)


def index_perf_simcore(doc):
    """Flatten a perf_simcore report into {key: (value, tolerance)}."""
    cells = {}
    for cell in doc.get("e2e", []):
        key = ("e2e", cell["scenario"], cell["groups"], cell["backend"])
        cells[key] = _entry(cell["events_per_sec"], DEFAULT_TOLERANCE)
    for cell in doc.get("queue_churn", []):
        key = ("churn", cell["backend"], cell["pending"])
        cells[key] = _entry(cell["events_per_sec"], DEFAULT_TOLERANCE)
    for cell in doc.get("parallel", []):
        key = ("parallel", cell["scenario"], cell["groups"], cell["exec"])
        cells[key] = _entry(cell["events_per_sec"], DEFAULT_TOLERANCE)
    for name in (
        "queue_speedup_largest_pending",
        "e2e_speedup_zipf_g4",
        "parallel_speedup_g2",
        "parallel_speedup_g4",
    ):
        cells[("ratio", name)] = _entry(doc.get(name, 0), RATIO_TOLERANCE)
    return cells


def index_fleet_scale(doc):
    """Flatten a fleet_scale report into {key: (value, tolerance)}."""
    cells = {}
    for cell in doc.get("cells", []):
        tag = (cell["models"], cell["dedup"], cell["policy"])
        cells[("goodput",) + tag] = _entry(cell["goodput"], DEFAULT_TOLERANCE)
        cells[("hit_rate",) + tag] = _entry(
            cell["host_hit_rate"], HIT_RATE_TOLERANCE
        )
    for name in ("dedup_goodput", "full_form_goodput"):
        cells[("total", name)] = _entry(doc.get(name, 0), DEFAULT_TOLERANCE)
    return cells


def index_planner_suite(doc):
    """Flatten a planner_suite report into {key: (value, tolerance)}."""
    cells = {}
    for arm in doc.get("scoring_workers", []):
        cells[("scoring", arm["workers"])] = _entry(
            arm["candidates_per_sec"], RATIO_TOLERANCE
        )
    cells[("ratio", "planner_speedup_workers4")] = _entry(
        doc.get("planner_speedup_workers4", 0), RATIO_TOLERANCE
    )
    for cell in doc.get("cells", []):
        for outcome in cell.get("outcomes", []):
            key = ("goodput", cell["scenario"], outcome["candidate"])
            cells[key] = _entry(outcome["goodput"], DEFAULT_TOLERANCE)
    return cells


REGISTRY = {
    "perf_simcore": index_perf_simcore,
    "fleet_scale": index_fleet_scale,
    "planner_suite": index_planner_suite,
}


def bench_name(doc):
    """The report's bench identity (``bench`` or legacy ``experiment``)."""
    return doc.get("bench") or doc.get("experiment")


def index_cells(doc):
    """Dispatch to the bench's indexer; raises ValueError when unknown."""
    name = bench_name(doc)
    if name not in REGISTRY:
        known = ", ".join(sorted(REGISTRY))
        raise ValueError(f"unregistered bench {name!r} (known: {known})")
    return REGISTRY[name](doc)


def _split(entry):
    """(value, tolerance) of a cell entry; bare numbers get the default."""
    if isinstance(entry, tuple):
        return entry
    return (entry, DEFAULT_TOLERANCE)


def advisory_reasons(baseline, new):
    """Reasons the comparison cannot bind (fail CI), in report order."""
    reasons = []
    if not baseline.get("calibrated", False):
        reasons.append("baseline is uncalibrated (bootstrap placeholder)")
    if baseline.get("fast") != new.get("fast"):
        reasons.append(
            f"mode mismatch: baseline fast={baseline.get('fast')} "
            f"vs new fast={new.get('fast')}"
        )
    return reasons


def compare_cells(base_cells, new_cells):
    """Diff two cell indexes.

    Returns ``(lines, regressions, compared)``: printable per-cell diff
    lines, the list of ``(key, base_value, new_value, ratio)`` tuples
    that regressed beyond the baseline cell's tolerance, and the number
    of comparable cells. Cells missing from the new run or with
    non-positive baseline values are skipped (unmeasured placeholders).
    """
    lines = []
    regressions = []
    compared = 0
    for key, entry in sorted(base_cells.items()):
        base_value, tolerance = _split(entry)
        if key not in new_cells or base_value <= 0:
            continue
        compared += 1
        new_value, _ = _split(new_cells[key])
        ratio = new_value / base_value
        marker = ""
        if ratio < 1.0 - tolerance:
            marker = "  << REGRESSION"
            regressions.append((key, base_value, new_value, ratio))
        lines.append(
            f"{'/'.join(str(k) for k in key):48s} "
            f"base {base_value:14.1f}  new {new_value:14.1f}  "
            f"ratio {ratio:5.2f} (tol {tolerance:.0%}){marker}"
        )
    return lines, regressions, compared


def calibrate(new_doc):
    """Promote a fresh run to a calibrated baseline document."""
    doc = dict(new_doc)
    doc["calibrated"] = True
    doc["note"] = (
        "Calibrated baseline generated by scripts/check_bench.py "
        "--calibrate from a real run on the CI reference machine. "
        "Regressions beyond each metric's tolerance now fail the "
        "perf-smoke job."
    )
    return doc


def run_diff(baseline_path, new_path):
    baseline = load(baseline_path)
    new = load(new_path)
    name = bench_name(baseline)
    if bench_name(new) != name:
        print(
            f"WARNING: bench mismatch (baseline {name!r} vs new "
            f"{bench_name(new)!r}); nothing to compare"
        )
        return 0
    try:
        base_cells = index_cells(baseline)
        new_cells = index_cells(new)
    except ValueError as e:
        print(f"WARNING: {e}; nothing to compare")
        return 0
    advisory = advisory_reasons(baseline, new)
    lines, regressions, compared = compare_cells(base_cells, new_cells)
    for line in lines:
        print(line)
    if compared == 0:
        print(f"WARNING: no comparable {name} cells between baseline and new run")
    if regressions:
        print(f"\n{len(regressions)} {name} cell(s) regressed beyond tolerance.")
        if advisory:
            print("ADVISORY ONLY (not failing):")
            for reason in advisory:
                print(f"  - {reason}")
            return 0
        return 1
    print(f"\n{name}: no regression beyond tolerance.")
    return 0


def run_calibrate(new_path, out_path):
    doc = calibrate(load(new_path))
    with open(out_path, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")
    name = bench_name(doc)
    try:
        cells = index_cells(doc)
    except ValueError:
        cells = {}
    measured = sum(1 for entry in cells.values() if _split(entry)[0] > 0)
    print(
        f"calibrated baseline written to {out_path} "
        f"({measured}/{len(cells)} cells measured); commit it as "
        f"BENCH_{name}.json to arm the perf gate"
    )
    return 0


def main(argv):
    if len(argv) == 4 and argv[1] == "--calibrate":
        return run_calibrate(argv[2], argv[3])
    if len(argv) == 3:
        return run_diff(argv[1], argv[2])
    sys.exit(__doc__)


if __name__ == "__main__":
    sys.exit(main(sys.argv))
